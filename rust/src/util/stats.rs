//! Execution statistics and structured tracing — SystemML's `-stats`.
//!
//! SystemML's `-stats` flag prints, after every script, (a) a *heavy
//! hitter* table of the top-k instructions by accumulated execution
//! time, (b) buffer-pool / caching counters, and (c) Spark-specific
//! counters (collects, broadcasts, parallelize). This module is that
//! subsystem for the reproduction, with each report section mapping to
//! a SystemML analogue:
//!
//! * **Heavy hitter instructions** (`-stats` "Heavy hitter
//!   instructions" table): every dispatched operator records invocation
//!   count, wall time, FLOPs and communication bytes keyed by
//!   `(op kind, source position, exec type CP/DIST)` — the exec-type
//!   split is SystemML's `CP`/`SP` instruction prefix, the source
//!   position is what `-explain`'s line attribution gives SystemML
//!   users.
//! * **Per-worker utilization** (no direct `-stats` analogue; Spark's
//!   per-executor task-time view in the UI): the blocked backend stamps
//!   each task's wall time against its simulated worker, giving busy
//!   time, task counts and a max/mean skew ratio — the input signal for
//!   straggler detection.
//! * **Structured trace** (SystemML's fine-grained `Statistics` +
//!   Spark's event log): an optional JSON-lines span log (session →
//!   script → statement → operator) with blockify / broadcast /
//!   shuffle / allreduce / cache-hit / cache-miss / spill / collect
//!   events carrying byte counts. Deterministic in everything except
//!   the `ts_ns` / `nanos` wall-time fields.
//! * **Serving latency breakdown** (no SystemML analogue; standard
//!   serving observability): `runtime::serve::run_simulation`
//!   attributes each request's latency to queue-wait vs execute vs
//!   scatter phases — see [`crate::runtime::serve::RequestPhases`].
//!
//! Collection is gated by [`SystemConfig::stats_enabled`] and
//! [`SystemConfig::trace_path`](crate::conf::SystemConfig): when both
//! are off no [`Stats`] object exists anywhere (every holder keeps an
//! `Option<Arc<Stats>>` that is `None`), so the disabled path costs a
//! single pointer check — no locks, no allocation.
//!
//! Counts, FLOPs and bytes in the report are byte-identical across
//! `dist_threads` settings because all of them are recorded driver-side
//! at dispatch time (the blocked backend's accounting discipline);
//! only wall-time fields vary run to run.
//!
//! [`SystemConfig::stats_enabled`]: crate::conf::SystemConfig::stats_enabled

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::conf::SystemConfig;

/// Key of one heavy-hitter table row: what ran, where in the script,
/// and on which backend.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct OpKey {
    /// Operator kind (SystemML instruction opcode, e.g. `ba+*`, `r'`).
    pub op: String,
    /// Source position `line:col` ("-" when synthetic).
    pub pos: String,
    /// Exec type: `"CP"` or `"DIST"` (SystemML's `CP`/`SP` prefix).
    pub exec: &'static str,
}

/// Accumulated measurements for one heavy-hitter row.
#[derive(Clone, Copy, Debug, Default)]
struct OpAccum {
    count: u64,
    nanos: u64,
    flops: u64,
    comm_bytes: u64,
}

/// One row of the heavy-hitter table (key + accumulated measurements).
#[derive(Clone, Debug)]
pub struct OpStat {
    pub op: String,
    pub pos: String,
    pub exec: &'static str,
    pub count: u64,
    /// Accumulated wall time (nondeterministic; everything else in this
    /// row is byte-identical across `dist_threads` settings).
    pub nanos: u64,
    pub flops: u64,
    pub comm_bytes: u64,
}

/// Per-worker utilization slot. The cluster stamps one of these per
/// simulated worker; `busy_nanos` is wall time of tasks attributed to
/// the worker (nondeterministic), `tasks` is the task count
/// (deterministic — block ownership does not depend on thread count).
#[derive(Debug, Default)]
pub struct WorkerSlot {
    pub busy_nanos: AtomicU64,
    pub tasks: AtomicU64,
}

/// Per-worker utilization row in a [`StatsReport`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WorkerStat {
    pub worker: usize,
    pub busy_nanos: u64,
    pub tasks: u64,
}

/// Structured snapshot of the statistics, for programmatic access
/// (`MLContext::stats()`); `render()` formats the human table.
#[derive(Clone, Debug, Default)]
pub struct StatsReport {
    /// All rows, sorted by key (deterministic order).
    pub ops: Vec<OpStat>,
    /// Per-worker utilization (empty when no distributed work ran).
    pub workers: Vec<WorkerStat>,
    /// Max/mean busy-time ratio across workers (1.0 when idle or only
    /// one worker) — the straggler-detector signal. Always finite.
    pub skew_ratio: f64,
}

impl StatsReport {
    /// Top-k rows by accumulated time (ties broken by key, so the
    /// ordering is stable when times collapse to zero).
    pub fn heavy_hitters(&self, k: usize) -> Vec<OpStat> {
        let mut rows = self.ops.clone();
        rows.sort_by(|a, b| {
            b.nanos
                .cmp(&a.nanos)
                .then_with(|| (&a.op, &a.pos, a.exec).cmp(&(&b.op, &b.pos, b.exec)))
        });
        rows.truncate(k);
        rows
    }
}

/// JSON-lines trace writer (one object per line, hand-rolled — no
/// serde). `seq` orders records; `ts_ns` is wall time since the session
/// opened and is the only nondeterministic field.
struct Tracer {
    state: Mutex<TracerState>,
    epoch: Instant,
}

struct TracerState {
    out: BufWriter<File>,
    seq: u64,
}

impl Tracer {
    fn write_line(&self, body: &str) {
        let mut st = self.state.lock().unwrap();
        st.seq += 1;
        let seq = st.seq;
        let ts = self.epoch.elapsed().as_nanos() as u64;
        // Trace I/O is best effort: a full disk must not fail the job.
        let _ = writeln!(st.out, "{{\"seq\":{seq},{body},\"ts_ns\":{ts}}}");
    }

    fn flush(&self) {
        let _ = self.state.lock().unwrap().out.flush();
    }
}

/// Escape a string for embedding in a JSON trace line.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// The statistics registry. One instance is shared (as
/// `Option<Arc<Stats>>`) by the `MLContext`, the `Interpreter` and the
/// `Cluster` of a session; `None` everywhere means stats are off and
/// the hot paths do no work.
pub struct Stats {
    /// Collect the per-op table (`stats_enabled`). The trace can be on
    /// with the table off and vice versa.
    table: bool,
    ops: Mutex<BTreeMap<OpKey, OpAccum>>,
    workers: Mutex<Vec<Arc<WorkerSlot>>>,
    tracer: Option<Tracer>,
}

impl std::fmt::Debug for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Stats(table={}, trace={})", self.table, self.tracer.is_some())
    }
}

impl Stats {
    /// Build the session's stats object from the config, or `None` when
    /// both knobs are off (the zero-cost path). A trace file that
    /// cannot be created is reported to stderr and tracing disabled —
    /// observability must not fail the job.
    pub fn from_config(config: &SystemConfig) -> Option<Arc<Stats>> {
        if !config.stats_enabled && config.trace_path.is_none() {
            return None;
        }
        let stats = Arc::new(Stats::new(
            config.stats_enabled,
            config.trace_path.as_deref(),
        ));
        stats.span_open("session", "mlcontext");
        Some(stats)
    }

    /// Build directly (tests and embedders). `from_config` is the
    /// normal entry point and also opens the session span.
    pub fn new(table: bool, trace_path: Option<&Path>) -> Stats {
        let tracer = trace_path.and_then(|p| match File::create(p) {
            Ok(f) => Some(Tracer {
                state: Mutex::new(TracerState { out: BufWriter::new(f), seq: 0 }),
                epoch: Instant::now(),
            }),
            Err(e) => {
                eprintln!("stats: cannot create trace file {}: {e}", p.display());
                None
            }
        });
        Stats {
            table,
            ops: Mutex::new(BTreeMap::new()),
            workers: Mutex::new(Vec::new()),
            tracer,
        }
    }

    /// Whether the per-op table is being collected.
    pub fn table_enabled(&self) -> bool {
        self.table
    }

    /// Whether trace records are being written.
    pub fn trace_enabled(&self) -> bool {
        self.tracer.is_some()
    }

    // ---- per-operator table -------------------------------------------

    /// Record one operator invocation. Called driver-side at dispatch
    /// time, so counts / FLOPs / bytes are deterministic; `nanos` is
    /// the only wall-time field.
    pub fn record_op(
        &self,
        op: &str,
        pos: &str,
        exec: &'static str,
        nanos: u64,
        flops: u64,
        comm_bytes: u64,
    ) {
        if self.table {
            let key = OpKey { op: op.to_string(), pos: pos.to_string(), exec };
            let mut ops = self.ops.lock().unwrap();
            let acc = ops.entry(key).or_default();
            acc.count += 1;
            acc.nanos += nanos;
            acc.flops += flops;
            acc.comm_bytes += comm_bytes;
        }
        if self.tracer.is_some() {
            self.span_close_op(op, pos, exec, nanos, flops, comm_bytes);
        }
    }

    // ---- per-worker utilization ---------------------------------------

    /// Register (growing on demand) and return the utilization slots
    /// for `n` workers. The cluster fetches these once at construction
    /// and stamps them per task, so the per-task path touches only
    /// atomics it already holds.
    pub fn worker_slots(&self, n: usize) -> Vec<Arc<WorkerSlot>> {
        let mut ws = self.workers.lock().unwrap();
        while ws.len() < n {
            ws.push(Arc::new(WorkerSlot::default()));
        }
        ws[..n].iter().map(Arc::clone).collect()
    }

    // ---- structured trace ---------------------------------------------

    /// Open a span (`session`, `script`, `statement`, `operator`).
    pub fn span_open(&self, kind: &str, name: &str) {
        if let Some(t) = &self.tracer {
            t.write_line(&format!(
                "\"ev\":\"span_open\",\"kind\":\"{}\",\"name\":\"{}\"",
                json_escape(kind),
                json_escape(name)
            ));
        }
    }

    /// Close a span opened with [`span_open`](Stats::span_open).
    pub fn span_close(&self, kind: &str, name: &str, nanos: u64) {
        if let Some(t) = &self.tracer {
            t.write_line(&format!(
                "\"ev\":\"span_close\",\"kind\":\"{}\",\"name\":\"{}\",\"nanos\":{nanos}",
                json_escape(kind),
                json_escape(name)
            ));
        }
    }

    /// Close an operator span, carrying its measurements.
    fn span_close_op(
        &self,
        op: &str,
        pos: &str,
        exec: &'static str,
        nanos: u64,
        flops: u64,
        comm_bytes: u64,
    ) {
        if let Some(t) = &self.tracer {
            t.write_line(&format!(
                "\"ev\":\"span_close\",\"kind\":\"operator\",\"name\":\"{}\",\"pos\":\"{}\",\
                 \"exec\":\"{exec}\",\"nanos\":{nanos},\"flops\":{flops},\"bytes\":{comm_bytes}",
                json_escape(op),
                json_escape(pos)
            ));
        }
    }

    /// Emit a point event (`blockify`, `broadcast`, `shuffle`,
    /// `allreduce`, `collect`, `spill`, `cache_hit`, `cache_miss`)
    /// with its byte count.
    pub fn event(&self, kind: &str, bytes: u64) {
        if let Some(t) = &self.tracer {
            t.write_line(&format!(
                "\"ev\":\"event\",\"kind\":\"{}\",\"bytes\":{bytes}",
                json_escape(kind)
            ));
        }
    }

    /// Flush the trace writer (tests read the file back mid-session).
    pub fn flush_trace(&self) {
        if let Some(t) = &self.tracer {
            t.flush();
        }
    }

    // ---- reporting -----------------------------------------------------

    /// Structured snapshot of the current counters.
    pub fn report(&self) -> StatsReport {
        let ops = self
            .ops
            .lock()
            .unwrap()
            .iter()
            .map(|(k, a)| OpStat {
                op: k.op.clone(),
                pos: k.pos.clone(),
                exec: k.exec,
                count: a.count,
                nanos: a.nanos,
                flops: a.flops,
                comm_bytes: a.comm_bytes,
            })
            .collect();
        let workers: Vec<WorkerStat> = self
            .workers
            .lock()
            .unwrap()
            .iter()
            .enumerate()
            .map(|(i, s)| WorkerStat {
                worker: i,
                busy_nanos: s.busy_nanos.load(Ordering::Relaxed),
                tasks: s.tasks.load(Ordering::Relaxed),
            })
            .collect();
        let skew_ratio = skew(&workers);
        StatsReport { ops, workers, skew_ratio }
    }

    /// Render the SystemML-style statistics text.
    pub fn render(&self, top_k: usize) -> String {
        let report = self.report();
        let mut out = String::new();
        out.push_str("SystemML Statistics:\n");
        out.push_str(&format!("Heavy hitter instructions (top {top_k} by time):\n"));
        out.push_str("  #   op               pos      exec  count     time(ms)        GFLOP      comm(KB)\n");
        for (i, row) in report.heavy_hitters(top_k).iter().enumerate() {
            out.push_str(&format!(
                "  {:<3} {:<16} {:<8} {:<5} {:<9} {:>12.3} {:>12.3} {:>13.1}\n",
                i + 1,
                row.op,
                row.pos,
                row.exec,
                row.count,
                row.nanos as f64 / 1e6,
                row.flops as f64 / 1e9,
                row.comm_bytes as f64 / 1024.0,
            ));
        }
        if report.ops.is_empty() {
            out.push_str("  (no operators recorded)\n");
        }
        out.push_str("Per-worker utilization:\n");
        if report.workers.is_empty() {
            out.push_str("  (no distributed work)\n");
        } else {
            out.push_str("  worker  tasks     busy(ms)\n");
            for w in &report.workers {
                out.push_str(&format!(
                    "  {:<7} {:<9} {:>10.3}\n",
                    w.worker,
                    w.tasks,
                    w.busy_nanos as f64 / 1e6,
                ));
            }
            out.push_str(&format!("  skew (max/mean busy): {:.3}\n", report.skew_ratio));
        }
        out
    }

    /// Clear the per-op table and worker slots (`reset_stats`). The
    /// trace file keeps appending — resets do not truncate history.
    pub fn reset(&self) {
        self.ops.lock().unwrap().clear();
        for s in self.workers.lock().unwrap().iter() {
            s.busy_nanos.store(0, Ordering::Relaxed);
            s.tasks.store(0, Ordering::Relaxed);
        }
    }
}

impl Drop for Stats {
    fn drop(&mut self) {
        // Balance the session span opened by `from_config` and flush.
        if let Some(t) = &self.tracer {
            t.write_line("\"ev\":\"span_close\",\"kind\":\"session\",\"name\":\"mlcontext\"");
            t.flush();
        }
    }
}

/// Max/mean busy-time ratio; 1.0 when there is no busy time at all so
/// the signal is always finite.
fn skew(workers: &[WorkerStat]) -> f64 {
    let total: u64 = workers.iter().map(|w| w.busy_nanos).sum();
    if workers.is_empty() || total == 0 {
        return 1.0;
    }
    let max = workers.iter().map(|w| w.busy_nanos).max().unwrap_or(0) as f64;
    let mean = total as f64 / workers.len() as f64;
    max / mean
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("sysml_stats_{}_{}", std::process::id(), name))
    }

    #[test]
    fn table_accumulates_by_key() {
        let s = Stats::new(true, None);
        s.record_op("ba+*", "3:9", "DIST", 10, 100, 4096);
        s.record_op("ba+*", "3:9", "DIST", 20, 100, 4096);
        s.record_op("ba+*", "5:1", "DIST", 5, 50, 0);
        s.record_op("ba+*", "3:9", "CP", 1, 2, 0);
        let r = s.report();
        assert_eq!(r.ops.len(), 3);
        let hot = r
            .ops
            .iter()
            .find(|o| o.pos == "3:9" && o.exec == "DIST")
            .expect("dist row present");
        assert_eq!(hot.count, 2);
        assert_eq!(hot.nanos, 30);
        assert_eq!(hot.flops, 200);
        assert_eq!(hot.comm_bytes, 8192);
    }

    #[test]
    fn heavy_hitters_sort_by_time_then_key() {
        let s = Stats::new(true, None);
        s.record_op("slow", "1:1", "CP", 100, 0, 0);
        s.record_op("fast", "2:1", "CP", 1, 0, 0);
        s.record_op("mid", "3:1", "CP", 50, 0, 0);
        let hh = s.report().heavy_hitters(2);
        assert_eq!(hh.len(), 2);
        assert_eq!(hh[0].op, "slow");
        assert_eq!(hh[1].op, "mid");
        // Zero-time ties fall back to key order.
        let s2 = Stats::new(true, None);
        s2.record_op("b", "1:1", "CP", 0, 0, 0);
        s2.record_op("a", "1:1", "CP", 0, 0, 0);
        let hh2 = s2.report().heavy_hitters(5);
        assert_eq!(hh2[0].op, "a");
    }

    #[test]
    fn disabled_table_records_nothing() {
        let s = Stats::new(false, None);
        s.record_op("ba+*", "1:1", "CP", 10, 10, 10);
        assert!(s.report().ops.is_empty());
    }

    #[test]
    fn worker_slots_grow_and_skew_is_finite() {
        let s = Stats::new(true, None);
        let slots = s.worker_slots(3);
        assert_eq!(slots.len(), 3);
        // Idle cluster: skew defined as 1.0 (finite).
        assert_eq!(s.report().skew_ratio, 1.0);
        slots[0].busy_nanos.store(300, Ordering::Relaxed);
        slots[0].tasks.store(3, Ordering::Relaxed);
        slots[1].busy_nanos.store(150, Ordering::Relaxed);
        slots[2].busy_nanos.store(150, Ordering::Relaxed);
        let r = s.report();
        assert_eq!(r.workers[0].tasks, 3);
        // max=300, mean=200 -> 1.5
        assert!((r.skew_ratio - 1.5).abs() < 1e-12);
        // Re-requesting fewer slots returns the same (shared) ones.
        let again = s.worker_slots(2);
        assert_eq!(again[0].busy_nanos.load(Ordering::Relaxed), 300);
        s.reset();
        assert_eq!(s.report().workers[0].busy_nanos, 0);
        assert!(s.report().ops.is_empty());
    }

    #[test]
    fn trace_writes_balanced_json_lines() {
        let path = tmp("trace_balanced");
        {
            let s = Stats::new(false, Some(&path));
            assert!(s.trace_enabled());
            s.span_open("script", "test");
            s.event("broadcast", 4096);
            s.record_op("ba+*", "1:1", "DIST", 5, 10, 4096);
            s.span_close("script", "test", 99);
            s.flush_trace();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        // span_open script, event, operator span_close, span_close
        // script (no session span: `new` doesn't open one).
        assert_eq!(lines.len(), 4);
        let mut opens = 0i64;
        let mut closes = 0i64;
        for line in &lines {
            let v = crate::util::json::Json::parse(line).expect("valid JSON line");
            match v.get("ev").as_str().unwrap() {
                "span_open" => opens += 1,
                "span_close" => closes += 1,
                _ => {}
            }
            assert!(v.get("seq").as_f64().is_some());
            assert!(v.get("ts_ns").as_f64().is_some());
        }
        assert_eq!(opens, 1);
        // operator span_close + script span_close (operator open spans
        // are emitted by the dispatcher, not by record_op).
        assert_eq!(closes, 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn json_escape_handles_specials() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(json_escape("plain"), "plain");
    }

    #[test]
    fn render_mentions_sections() {
        let s = Stats::new(true, None);
        s.record_op("ba+*", "3:9", "DIST", 1_000_000, 2_000_000_000, 2048);
        let slots = s.worker_slots(2);
        slots[0].busy_nanos.store(10, Ordering::Relaxed);
        let text = s.render(5);
        assert!(text.contains("Heavy hitter instructions"));
        assert!(text.contains("ba+*"));
        assert!(text.contains("Per-worker utilization"));
        assert!(text.contains("skew (max/mean busy)"));
    }
}
