//! Property-testing helper (proptest is unavailable offline).
//!
//! `forall` drives a property over `cases` random inputs drawn from a
//! generator closure; on failure it re-runs the generator seed and reports
//! the failing case index + seed so the case can be reproduced
//! deterministically. Shrinking is approximated by `forall_sized`, which
//! retries failures at smaller size parameters first.

use crate::util::prng::Prng;

/// Number of cases run per property by default.
pub const DEFAULT_CASES: usize = 64;

/// Run `prop` on `cases` inputs produced by `gen`. Panics with a
/// reproducible seed on the first failure.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Prng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    for case in 0..cases {
        let seed = 0x5EED_0000 + case as u64;
        let mut rng = Prng::new(seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}):\n{input:#?}");
        }
    }
}

/// Like [`forall`], but the generator receives a size parameter that grows
/// with the case index — small counterexamples are found first, which is a
/// poor man's shrinking.
pub fn forall_sized<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    max_size: usize,
    mut gen: impl FnMut(&mut Prng, usize) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    for case in 0..cases {
        let seed = 0x51ED_0000 + case as u64;
        let size = 1 + case * max_size / cases.max(1);
        let mut rng = Prng::new(seed);
        let input = gen(&mut rng, size);
        if !prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}, size {size}):\n{input:#?}"
            );
        }
    }
}

/// Assert two f64s are close (absolute + relative tolerance).
pub fn approx_eq(a: f64, b: f64, tol: f64) -> bool {
    if a == b {
        return true;
    }
    if a.is_nan() && b.is_nan() {
        return true;
    }
    let diff = (a - b).abs();
    diff <= tol || diff <= tol * a.abs().max(b.abs())
}

/// Assert two slices are elementwise close.
pub fn approx_eq_slice(a: &[f64], b: &[f64], tol: f64) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| approx_eq(*x, *y, tol))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_true_property() {
        forall("x*0==0", 32, |r| r.uniform(-1e6, 1e6), |x| x * 0.0 == 0.0);
    }

    #[test]
    #[should_panic(expected = "property 'always-false'")]
    fn forall_reports_failures() {
        forall("always-false", 8, |r| r.next_u64(), |_| false);
    }

    #[test]
    fn approx_eq_tolerances() {
        assert!(approx_eq(1.0, 1.0 + 1e-12, 1e-9));
        assert!(approx_eq(1e9, 1e9 * (1.0 + 1e-10), 1e-9));
        assert!(!approx_eq(1.0, 1.1, 1e-9));
        assert!(approx_eq(f64::NAN, f64::NAN, 1e-9));
    }
}
