//! Global runtime metrics.
//!
//! SystemML exposes statistics (`-stats`) about executed instructions,
//! FLOPs, spark shuffle volume, GPU transfers etc. We keep the analogous
//! counters here as process-global atomics so the benches can attribute
//! work (e.g. FLOP reduction of sparse operators, shuffle bytes of
//! distributed plans) without threading a handle everywhere.

use std::sync::atomic::{AtomicU64, Ordering};

/// Process-global counters. All methods are lock-free.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Floating point operations executed by matrix kernels (mul+add = 2).
    pub flops: AtomicU64,
    /// Bytes moved through simulated-cluster shuffles.
    pub shuffle_bytes: AtomicU64,
    /// Tree-allreduce reduction rounds executed (log2(workers) per op).
    pub allreduce_rounds: AtomicU64,
    /// Bytes moved by tree-allreduce rounds (also charged to
    /// `shuffle_bytes` — this counter attributes the allreduce share).
    pub allreduce_bytes: AtomicU64,
    /// Bytes broadcast to simulated workers.
    pub broadcast_bytes: AtomicU64,
    /// Distributed tasks launched.
    pub dist_tasks: AtomicU64,
    /// Local-matrix -> blocked-partition conversions (SystemML blockify).
    pub blockify_ops: AtomicU64,
    /// Blocked -> driver-local collects (SystemML collect-to-driver).
    pub dist_collects: AtomicU64,
    /// Live blocked values spilled to the driver under storage pressure.
    pub dist_spills: AtomicU64,
    /// Block-partition cache hits (resident blocked matrix reused).
    pub cache_hits: AtomicU64,
    /// Block-partition cache misses (blockify performed).
    pub cache_misses: AtomicU64,
    /// Block-partition cache evictions (LRU under the storage budget).
    pub cache_evictions: AtomicU64,
    /// parfor tasks launched.
    pub parfor_tasks: AtomicU64,
    /// Task batches executed on the dist worker thread pool (parallel
    /// mode only; serial `threads=1` batches run inline and don't count).
    pub pool_batches: AtomicU64,
    /// Individual tasks executed on dist worker pool threads.
    pub pool_tasks: AtomicU64,
    /// Host->device bytes copied by the accelerator backend.
    pub h2d_bytes: AtomicU64,
    /// Device->host bytes copied by the accelerator backend.
    pub d2h_bytes: AtomicU64,
    /// Device buffer evictions (LRU).
    pub device_evictions: AtomicU64,
    /// Accelerator executions.
    pub accel_launches: AtomicU64,
    /// Interpreter instructions executed.
    pub instructions: AtomicU64,
    /// Sparse-operator invocations (any of the sparse physical operators).
    pub sparse_ops: AtomicU64,
    /// Dense-operator invocations.
    pub dense_ops: AtomicU64,
}

static GLOBAL: Metrics = Metrics {
    flops: AtomicU64::new(0),
    shuffle_bytes: AtomicU64::new(0),
    allreduce_rounds: AtomicU64::new(0),
    allreduce_bytes: AtomicU64::new(0),
    broadcast_bytes: AtomicU64::new(0),
    dist_tasks: AtomicU64::new(0),
    blockify_ops: AtomicU64::new(0),
    dist_collects: AtomicU64::new(0),
    dist_spills: AtomicU64::new(0),
    cache_hits: AtomicU64::new(0),
    cache_misses: AtomicU64::new(0),
    cache_evictions: AtomicU64::new(0),
    parfor_tasks: AtomicU64::new(0),
    pool_batches: AtomicU64::new(0),
    pool_tasks: AtomicU64::new(0),
    h2d_bytes: AtomicU64::new(0),
    d2h_bytes: AtomicU64::new(0),
    device_evictions: AtomicU64::new(0),
    accel_launches: AtomicU64::new(0),
    instructions: AtomicU64::new(0),
    sparse_ops: AtomicU64::new(0),
    dense_ops: AtomicU64::new(0),
};

/// Access the global metrics instance.
pub fn global() -> &'static Metrics {
    &GLOBAL
}

impl Metrics {
    #[inline]
    pub fn add_flops(&self, n: u64) {
        self.flops.fetch_add(n, Ordering::Relaxed);
    }
    #[inline]
    pub fn add_shuffle(&self, bytes: u64) {
        self.shuffle_bytes.fetch_add(bytes, Ordering::Relaxed);
    }
    #[inline]
    pub fn add_broadcast(&self, bytes: u64) {
        self.broadcast_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Snapshot all counters.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            flops: self.flops.load(Ordering::Relaxed),
            shuffle_bytes: self.shuffle_bytes.load(Ordering::Relaxed),
            allreduce_rounds: self.allreduce_rounds.load(Ordering::Relaxed),
            allreduce_bytes: self.allreduce_bytes.load(Ordering::Relaxed),
            broadcast_bytes: self.broadcast_bytes.load(Ordering::Relaxed),
            dist_tasks: self.dist_tasks.load(Ordering::Relaxed),
            blockify_ops: self.blockify_ops.load(Ordering::Relaxed),
            dist_collects: self.dist_collects.load(Ordering::Relaxed),
            dist_spills: self.dist_spills.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            cache_evictions: self.cache_evictions.load(Ordering::Relaxed),
            parfor_tasks: self.parfor_tasks.load(Ordering::Relaxed),
            pool_batches: self.pool_batches.load(Ordering::Relaxed),
            pool_tasks: self.pool_tasks.load(Ordering::Relaxed),
            h2d_bytes: self.h2d_bytes.load(Ordering::Relaxed),
            d2h_bytes: self.d2h_bytes.load(Ordering::Relaxed),
            device_evictions: self.device_evictions.load(Ordering::Relaxed),
            accel_launches: self.accel_launches.load(Ordering::Relaxed),
            instructions: self.instructions.load(Ordering::Relaxed),
            sparse_ops: self.sparse_ops.load(Ordering::Relaxed),
            dense_ops: self.dense_ops.load(Ordering::Relaxed),
        }
    }

    /// Reset all counters to zero (benches call this between configs).
    pub fn reset(&self) {
        self.flops.store(0, Ordering::Relaxed);
        self.shuffle_bytes.store(0, Ordering::Relaxed);
        self.allreduce_rounds.store(0, Ordering::Relaxed);
        self.allreduce_bytes.store(0, Ordering::Relaxed);
        self.broadcast_bytes.store(0, Ordering::Relaxed);
        self.dist_tasks.store(0, Ordering::Relaxed);
        self.blockify_ops.store(0, Ordering::Relaxed);
        self.dist_collects.store(0, Ordering::Relaxed);
        self.dist_spills.store(0, Ordering::Relaxed);
        self.cache_hits.store(0, Ordering::Relaxed);
        self.cache_misses.store(0, Ordering::Relaxed);
        self.cache_evictions.store(0, Ordering::Relaxed);
        self.parfor_tasks.store(0, Ordering::Relaxed);
        self.pool_batches.store(0, Ordering::Relaxed);
        self.pool_tasks.store(0, Ordering::Relaxed);
        self.h2d_bytes.store(0, Ordering::Relaxed);
        self.d2h_bytes.store(0, Ordering::Relaxed);
        self.device_evictions.store(0, Ordering::Relaxed);
        self.accel_launches.store(0, Ordering::Relaxed);
        self.instructions.store(0, Ordering::Relaxed);
        self.sparse_ops.store(0, Ordering::Relaxed);
        self.dense_ops.store(0, Ordering::Relaxed);
    }
}

/// Plain-old-data snapshot of [`Metrics`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub flops: u64,
    pub shuffle_bytes: u64,
    pub allreduce_rounds: u64,
    pub allreduce_bytes: u64,
    pub broadcast_bytes: u64,
    pub dist_tasks: u64,
    pub blockify_ops: u64,
    pub dist_collects: u64,
    pub dist_spills: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
    pub parfor_tasks: u64,
    pub pool_batches: u64,
    pub pool_tasks: u64,
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
    pub device_evictions: u64,
    pub accel_launches: u64,
    pub instructions: u64,
    pub sparse_ops: u64,
    pub dense_ops: u64,
}

/// A [`MetricsSnapshot`] interpreted as counter *deltas* between two
/// snapshots. The global atomics bleed across concurrent clusters and
/// tests; assertions must always be phrased over a delta
/// (`after.delta(&before)`) so a parallel test run can only *inflate*
/// a window, never subtract from it — never over raw counter loads.
pub type MetricsDelta = MetricsSnapshot;

impl MetricsSnapshot {
    /// Counter deltas since `earlier`.
    pub fn delta(&self, earlier: &MetricsSnapshot) -> MetricsDelta {
        MetricsSnapshot {
            flops: self.flops - earlier.flops,
            shuffle_bytes: self.shuffle_bytes - earlier.shuffle_bytes,
            allreduce_rounds: self.allreduce_rounds - earlier.allreduce_rounds,
            allreduce_bytes: self.allreduce_bytes - earlier.allreduce_bytes,
            broadcast_bytes: self.broadcast_bytes - earlier.broadcast_bytes,
            dist_tasks: self.dist_tasks - earlier.dist_tasks,
            blockify_ops: self.blockify_ops - earlier.blockify_ops,
            dist_collects: self.dist_collects - earlier.dist_collects,
            dist_spills: self.dist_spills - earlier.dist_spills,
            cache_hits: self.cache_hits - earlier.cache_hits,
            cache_misses: self.cache_misses - earlier.cache_misses,
            cache_evictions: self.cache_evictions - earlier.cache_evictions,
            parfor_tasks: self.parfor_tasks - earlier.parfor_tasks,
            pool_batches: self.pool_batches - earlier.pool_batches,
            pool_tasks: self.pool_tasks - earlier.pool_tasks,
            h2d_bytes: self.h2d_bytes - earlier.h2d_bytes,
            d2h_bytes: self.d2h_bytes - earlier.d2h_bytes,
            device_evictions: self.device_evictions - earlier.device_evictions,
            accel_launches: self.accel_launches - earlier.accel_launches,
            instructions: self.instructions - earlier.instructions,
            sparse_ops: self.sparse_ops - earlier.sparse_ops,
            dense_ops: self.dense_ops - earlier.dense_ops,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_tracks_increments() {
        let before = global().snapshot();
        global().add_flops(100);
        global().add_shuffle(64);
        let after = global().snapshot();
        let d = after.delta(&before);
        assert!(d.flops >= 100);
        assert!(d.shuffle_bytes >= 64);
    }
}
