//! Accelerator backend integration: load AOT artifacts, execute via PJRT,
//! and check numerics against the CP runtime. Requires `make artifacts`.

use systemml::conf::SystemConfig;
use systemml::runtime::accel::AccelBackend;
use systemml::runtime::conv::{self, ConvShape};
use systemml::runtime::matrix::randgen::{rand, synthetic_classification, Pdf};
use systemml::runtime::matrix::{mult, Matrix};
use systemml::util::quickcheck::approx_eq_slice;

fn backend() -> Option<AccelBackend> {
    let mut config = SystemConfig::default();
    config.accel_enabled = true;
    match AccelBackend::open(&config) {
        Ok(b) => Some(b),
        Err(e) => {
            // Artifacts not built: skip (CI runs `make artifacts` first).
            eprintln!("skipping accel tests: {e}");
            None
        }
    }
}

#[test]
fn matmul_offload_matches_cp() {
    let Some(b) = backend() else { return };
    let x = rand(256, 256, -1.0, 1.0, 1.0, Pdf::Uniform, 1).unwrap();
    let y = rand(256, 256, -1.0, 1.0, 1.0, Pdf::Uniform, 2).unwrap();
    let accel = b.try_matmult(&x, &y).unwrap().expect("256^3 artifact exists");
    let cp = mult::matmult(&x, &y).unwrap();
    assert!(approx_eq_slice(&accel.to_row_major_vec(), &cp.to_row_major_vec(), 1e-9));
}

#[test]
fn matmul_without_artifact_falls_back() {
    let Some(b) = backend() else { return };
    let x = Matrix::filled(33, 17, 1.0);
    let y = Matrix::filled(17, 5, 1.0);
    assert!(b.try_matmult(&x, &y).unwrap().is_none(), "no artifact for 33x17x5");
}

#[test]
fn conv2d_offload_matches_cp() {
    let Some(b) = backend() else { return };
    let sh = ConvShape { c: 1, h: 28, w: 28, k: 8, r: 3, s: 3, stride: (1, 1), pad: (1, 1) };
    let x = rand(16, 784, 0.0, 1.0, 1.0, Pdf::Uniform, 3).unwrap();
    let w = rand(8, 9, -1.0, 1.0, 1.0, Pdf::Uniform, 4).unwrap();
    let accel = b.try_conv2d(&x, &w, &sh).unwrap().expect("lenet conv1 artifact");
    let cp = conv::conv2d(&x, &w, &sh).unwrap();
    assert!(approx_eq_slice(&accel.to_row_major_vec(), &cp.to_row_major_vec(), 1e-9));
}

#[test]
fn fused_train_step_matches_dml_script() {
    // The fused softmax_train_step artifact must compute exactly what the
    // paper's §2 DML script computes for one iteration.
    let Some(b) = backend() else { return };
    let (x_all, y_all) = synthetic_classification(32, 784, 10, 5);
    let w0 = rand(784, 10, -0.1, 0.1, 1.0, Pdf::Uniform, 6).unwrap();
    let b0 = Matrix::zeros(1, 10).into_dense_format();

    // Accel step.
    let outs = b
        .run_named("softmax_train_step_bs32_d784_k10", &[&x_all, &w0, &b0, &y_all])
        .unwrap();
    assert_eq!(outs.len(), 3);

    // CP step via DML.
    let ctx = systemml::MLContext::new();
    let script = systemml::Script::from_str(
        r#"
        source("nn/layers/softmax.dml") as softmax
        source("nn/layers/cross_entropy_loss.dml") as ce
        N = nrow(X)
        scores = X %*% W + b
        probs = softmax::forward(scores)
        loss = ce::forward(probs, Y)
        dscores = (probs - Y) / N
        W2 = W - 0.1 * (t(X) %*% dscores)
        b2 = b - 0.1 * colSums(dscores)
        "#,
    )
    .input("X", x_all)
    .input("Y", y_all)
    .input("W", w0)
    .input("b", b0)
    .output("W2")
    .output("b2")
    .output("loss");
    let res = ctx.execute(script).unwrap();

    assert!(approx_eq_slice(
        &outs[0].to_row_major_vec(),
        &res.matrix("W2").unwrap().to_row_major_vec(),
        1e-9
    ));
    assert!(approx_eq_slice(
        &outs[1].to_row_major_vec(),
        &res.matrix("b2").unwrap().to_row_major_vec(),
        1e-9
    ));
    let accel_loss = outs[2].get(0, 0);
    let cp_loss = res.double("loss").unwrap();
    assert!((accel_loss - cp_loss).abs() < 1e-9, "loss {accel_loss} vs {cp_loss}");
}

#[test]
fn accel_metrics_recorded() {
    let Some(b) = backend() else { return };
    let before = systemml::util::metrics::global().snapshot();
    let x = rand(256, 256, -1.0, 1.0, 1.0, Pdf::Uniform, 7).unwrap();
    let y = rand(256, 256, -1.0, 1.0, 1.0, Pdf::Uniform, 8).unwrap();
    b.try_matmult(&x, &y).unwrap().unwrap();
    let d = systemml::util::metrics::global().snapshot().delta(&before);
    assert!(d.accel_launches >= 1);
    assert!(d.h2d_bytes >= (2 * 256 * 256 * 8) as u64);
    assert!(d.d2h_bytes >= (256 * 256 * 8) as u64);
}

#[test]
fn compile_cache_reused() {
    let Some(b) = backend() else { return };
    let x = rand(256, 256, -1.0, 1.0, 1.0, Pdf::Uniform, 9).unwrap();
    let y = rand(256, 256, -1.0, 1.0, 1.0, Pdf::Uniform, 10).unwrap();
    let t0 = std::time::Instant::now();
    b.try_matmult(&x, &y).unwrap().unwrap();
    let first = t0.elapsed();
    let t1 = std::time::Instant::now();
    for _ in 0..3 {
        b.try_matmult(&x, &y).unwrap().unwrap();
    }
    let warm = t1.elapsed() / 3;
    assert!(warm < first, "warm {warm:?} should be faster than cold {first:?} (compile cached)");
}

#[test]
fn dml_script_uses_accel_when_enabled() {
    // conv2d builtin routed through the accelerator from DML.
    let mut config = SystemConfig::default();
    config.accel_enabled = true;
    if AccelBackend::open(&config).is_err() {
        return;
    }
    let ctx = systemml::MLContext::with_config(config);
    let before = systemml::util::metrics::global().snapshot();
    let script = systemml::Script::from_str(
        r#"
        X = rand(rows=16, cols=784, min=0, max=1, seed=1)
        W = rand(rows=8, cols=9, min=-1, max=1, seed=2)
        out = conv2d(X, W, input_shape=[16,1,28,28], filter_shape=[8,1,3,3],
                     stride=[1,1], padding=[1,1])
        s = sum(out)
        "#,
    )
    .output("s");
    let res = ctx.execute(script).unwrap();
    let d = systemml::util::metrics::global().snapshot().delta(&before);
    assert!(d.accel_launches >= 1, "conv2d should offload to the accelerator");
    assert!(res.double("s").unwrap().is_finite());
}

#[test]
fn pallas_twin_artifacts_match_native() {
    // L1 validation: the interpret-mode Pallas kernel graphs must compute
    // exactly what the XLA-native graphs compute (same HLO interface).
    let Some(b) = backend() else { return };
    let x = rand(384, 384, -1.0, 1.0, 1.0, Pdf::Uniform, 21).unwrap();
    let y = rand(384, 384, -1.0, 1.0, 1.0, Pdf::Uniform, 22).unwrap();
    let native = b.run_named("matmul_384x384x384", &[&x, &y]).unwrap();
    let pallas = b.run_named("matmul_384x384x384_pallas", &[&x, &y]).unwrap();
    assert!(approx_eq_slice(
        &native[0].to_row_major_vec(),
        &pallas[0].to_row_major_vec(),
        1e-12
    ));

    let (xs, ys) = synthetic_classification(32, 784, 10, 23);
    let w0 = rand(784, 10, -0.1, 0.1, 1.0, Pdf::Uniform, 24).unwrap();
    let b0 = Matrix::zeros(1, 10).into_dense_format();
    let native = b.run_named("softmax_train_step_bs32_d784_k10", &[&xs, &w0, &b0, &ys]).unwrap();
    let pallas =
        b.run_named("softmax_train_step_bs32_d784_k10_pallas", &[&xs, &w0, &b0, &ys]).unwrap();
    for (n, p) in native.iter().zip(&pallas) {
        assert!(approx_eq_slice(&n.to_row_major_vec(), &p.to_row_major_vec(), 1e-12));
    }
}
