//! Sparse blocked backend: per-block dense/CSR grids agree with local
//! (CP) execution, format transitions flow both directions, nnz stays
//! exact through block rewrites, cache guards see content (not
//! representation), and results are byte-identical across thread counts.

use systemml::runtime::dist::cache::LineageRef;
use systemml::runtime::dist::{ops, BlockedMatrix, Cluster};
use systemml::runtime::matrix::elementwise::BinOp;
use systemml::runtime::matrix::randgen::{rand, Pdf};
use systemml::runtime::matrix::{elementwise, mult, reorg, Matrix};
use systemml::util::quickcheck::approx_eq_slice;

/// A matrix whose blockified grid genuinely mixes formats: a ~2%-dense
/// background with a fully dense patch covering the top-left block, so
/// with block size 64 block (0,0) stays dense while the rest go CSR.
fn mixed(rows: usize, cols: usize, seed: u64) -> Matrix {
    let base = rand(rows, cols, -1.0, 1.0, 0.02, Pdf::Uniform, seed).unwrap();
    let pr = 64.min(rows);
    let pc = 64.min(cols);
    let patch = rand(pr, pc, -1.0, 1.0, 1.0, Pdf::Uniform, seed ^ 0x9e37).unwrap();
    reorg::left_index(&base, 0, 0, &patch).unwrap()
}

#[test]
fn blockify_mixes_formats_and_keeps_nnz() {
    let cluster = Cluster::new(4, 64);
    let m = mixed(256, 192, 41);
    let b = cluster.blockify(&m).unwrap();
    let mut sparse_blocks = 0usize;
    let mut dense_blocks = 0usize;
    for i in 0..b.block_rows() {
        for j in 0..b.block_cols() {
            if b.block(i, j).is_sparse() {
                sparse_blocks += 1;
            } else {
                dense_blocks += 1;
            }
        }
    }
    assert!(sparse_blocks > 0, "low-density blocks should be CSR");
    assert!(dense_blocks > 0, "the dense patch block should stay dense");
    assert_eq!(b.nnz(), m.nnz());
    assert_eq!(b.to_local().unwrap(), m);
}

#[test]
fn mixed_grid_matmult_matches_cp() {
    let cluster = Cluster::new(4, 64);
    // sparse×dense, dense×sparse and sparse×sparse block pairings all
    // occur inside these grids.
    let a = mixed(192, 160, 51);
    let d = rand(160, 128, -1.0, 1.0, 1.0, Pdf::Uniform, 52).unwrap();
    let s = rand(160, 128, -1.0, 1.0, 0.02, Pdf::Uniform, 53).unwrap();
    for rhs in [&d, &s] {
        let local = mult::matmult(&a, rhs).unwrap();
        let dist = ops::matmult(&cluster, &a, rhs).unwrap();
        assert!(approx_eq_slice(
            &dist.to_row_major_vec(),
            &local.to_row_major_vec(),
            1e-9
        ));
    }
}

#[test]
fn mixed_grid_cellwise_transpose_slice_match_cp_exactly() {
    let cluster = Cluster::new(3, 64);
    let x = mixed(200, 150, 61);
    let y = mixed(200, 150, 62);
    let xb = cluster.blockify(&x).unwrap();
    let yb = cluster.blockify(&y).unwrap();
    // Cellwise maps, transpose and slice apply the same per-cell kernel
    // in both backends — results are byte-identical, not just close.
    for op in [BinOp::Add, BinOp::Mul, BinOp::Min] {
        let local = elementwise::binary(&x, &y, op).unwrap();
        let dist = ops::binary_blocked(&cluster, &xb, &yb, op).unwrap();
        assert_eq!(dist.to_local().unwrap(), local);
        assert_eq!(dist.nnz(), local.nnz(), "{op:?} nnz drifted");
    }
    let local_t = reorg::transpose(&x);
    let dist_t = ops::transpose_blocked(&cluster, &xb);
    assert_eq!(dist_t.to_local().unwrap(), local_t);
    assert_eq!(dist_t.nnz(), x.nnz());
    // Block-misaligned slice exercises the straddling gather path.
    let local_s = reorg::slice(&x, 3, 131, 5, 140).unwrap();
    let dist_s = ops::slice_blocked(&cluster, &xb, 3, 131, 5, 140).unwrap();
    assert_eq!(dist_s.to_local().unwrap(), local_s);
    assert_eq!(dist_s.nnz(), local_s.nnz());
}

#[test]
fn left_index_rewrites_keep_nnz_exact() {
    let cluster = Cluster::new(4, 64);
    let x = rand(200, 200, -1.0, 1.0, 0.01, Pdf::Uniform, 71).unwrap();
    let xb = cluster.blockify(&x).unwrap();
    // A patch that both adds and erases nonzeros: dense values with an
    // all-zero stripe, written across block boundaries.
    let mut patch = rand(70, 90, -1.0, 1.0, 1.0, Pdf::Uniform, 72).unwrap().to_dense();
    for c in 0..90 {
        patch.set(10, c, 0.0);
    }
    let patch = Matrix::Dense(patch);
    let local = reorg::left_index(&x, 30, 40, &patch).unwrap();
    let dist = ops::left_index_blocked(&cluster, &xb, 30, 40, &patch, false).unwrap();
    assert_eq!(dist.to_local().unwrap(), local);
    assert_eq!(dist.nnz(), local.nnz());
    // Fill with zero erases the region's nonzeros; nnz must track that.
    let local_fill = reorg::left_index(&x, 0, 0, &Matrix::zeros(64, 64)).unwrap();
    let dist_fill = ops::left_index_fill_blocked(&cluster, &xb, 0, 64, 0, 64, 0.0).unwrap();
    assert_eq!(dist_fill.to_local().unwrap(), local_fill);
    assert_eq!(dist_fill.nnz(), local_fill.nnz());
}

#[test]
fn ops_transition_block_formats_both_directions() {
    let cluster = Cluster::new(4, 64);
    // dense → sparse: a dense lhs times a rhs with a single nonzero
    // column yields output blocks far below the turn point, so the
    // matmult re-examines them into CSR.
    let a = rand(128, 128, -1.0, 1.0, 1.0, Pdf::Uniform, 81).unwrap();
    let mut rhs = Matrix::zeros(128, 128).to_dense();
    for r in 0..128 {
        rhs.set(r, 3, 1.0);
    }
    let ab = cluster.blockify(&a).unwrap();
    let rb = cluster.blockify(&Matrix::Dense(rhs)).unwrap();
    let prod = ops::matmult_blocked(&cluster, &ab, &rb).unwrap();
    assert!(
        (0..prod.block_rows()).any(|i| prod.block(i, 0).is_sparse()),
        "near-empty matmult output blocks should convert to CSR"
    );
    // sparse → dense: writing a dense patch over a CSR block re-examines
    // it back to dense; untouched blocks keep their format.
    let x = rand(128, 128, -1.0, 1.0, 0.01, Pdf::Uniform, 82).unwrap();
    let xb = cluster.blockify(&x).unwrap();
    assert!(xb.block(0, 0).is_sparse() && xb.block(1, 1).is_sparse());
    let patch = rand(64, 64, -1.0, 1.0, 1.0, Pdf::Uniform, 83).unwrap();
    let out = ops::left_index_blocked(&cluster, &xb, 0, 0, &patch, false).unwrap();
    assert!(!out.block(0, 0).is_sparse(), "dense patch should flip the block to dense");
    assert!(out.block(1, 1).is_sparse(), "untouched block keeps CSR");
}

#[test]
fn sparsity_threshold_knob_controls_formats_end_to_end() {
    let m = rand(256, 256, -1.0, 1.0, 0.05, Pdf::Uniform, 91).unwrap();
    let force_dense = Cluster::new(2, 64).with_sparsity_threshold(0.0);
    let b = force_dense.blockify(&m).unwrap();
    assert!((0..b.block_rows())
        .all(|i| (0..b.block_cols()).all(|j| !b.block(i, j).is_sparse())));
    let force_sparse = Cluster::new(2, 64).with_sparsity_threshold(1.0);
    let b = force_sparse.blockify(&m).unwrap();
    assert!((0..b.block_rows())
        .all(|i| (0..b.block_cols()).all(|j| b.block(i, j).is_sparse())));
}

#[test]
fn cache_guard_sees_content_not_representation() {
    // 300×300 = 90k cells: large enough to take the sampled-guard path.
    let cluster = Cluster::with_storage(2, 64, 1 << 22);
    let dense = rand(300, 300, -1.0, 1.0, 0.05, Pdf::Uniform, 101).unwrap().to_dense();
    let dense = Matrix::Dense(dense);
    let sparse = dense.clone().examine_and_convert();
    assert!(sparse.is_sparse(), "5% density should convert");
    let h = LineageRef::var("X", 1);
    let (_, first) = cluster.acquire_blocked(Some(&h), &dense).unwrap();
    assert!(!first.is_hit());
    // Same logical content in CSR form: the guard fingerprints cells,
    // not the encoding, so this is a legitimate hit.
    let (_, refetch) = cluster.acquire_blocked(Some(&h), &sparse).unwrap();
    assert!(refetch.is_hit(), "representation change alone must not evict");
    // Content change that also flips the format (mass zeroing) must
    // never ride the cached dense value: nnz drift breaks the guard.
    let mut drifted = dense.to_dense();
    for r in 0..300 {
        for c in 0..300 {
            if (r + c) % 7 != 0 {
                drifted.set(r, c, 0.0);
            }
        }
    }
    let drifted = Matrix::Dense(drifted).examine_and_convert();
    assert!(drifted.is_sparse());
    let (got, third) = cluster.acquire_blocked(Some(&h), &drifted).unwrap();
    assert!(!third.is_hit(), "dense→sparse content change must miss");
    assert_eq!(got.to_local().unwrap(), drifted);
}

#[test]
fn results_byte_identical_across_thread_counts() {
    let run = |threads: usize| -> (Vec<f64>, u64) {
        let cluster = Cluster::with_threads(4, 64, threads);
        let x = mixed(192, 160, 111);
        let w = rand(160, 96, -1.0, 1.0, 1.0, Pdf::Uniform, 112).unwrap();
        let xb = cluster.blockify(&x).unwrap();
        let wb = cluster.blockify(&w).unwrap();
        let p = ops::matmult_blocked(&cluster, &xb, &wb).unwrap();
        let s = ops::scalar_blocked(&cluster, &p, 0.5, BinOp::Mul, false).unwrap();
        let t = ops::transpose_blocked(&cluster, &s);
        let sl = ops::slice_blocked(&cluster, &t, 1, 90, 2, 130).unwrap();
        (sl.to_row_major_vec(), cluster.comm_bytes())
    };
    let (v1, c1) = run(1);
    let (v4, c4) = run(4);
    // Bit-for-bit equal outputs and identical comm accounting: the task
    // pool preserves submission order regardless of thread count.
    assert_eq!(v1, v4);
    assert_eq!(c1, c4);
}

#[test]
fn sparse_comm_is_charged_by_encoded_bytes() {
    let comm_for = |density: f64, seed: u64| -> u64 {
        let cluster = Cluster::new(4, 64);
        let a = rand(512, 256, -1.0, 1.0, 1.0, Pdf::Uniform, seed).unwrap();
        let b = rand(256, 128, -1.0, 1.0, density, Pdf::Uniform, seed + 1).unwrap();
        ops::matmult(&cluster, &a, &b).unwrap();
        cluster.comm_bytes()
    };
    let dense_bytes = comm_for(1.0, 121);
    let sparse_bytes = comm_for(0.01, 123);
    assert!(sparse_bytes > 0);
    assert!(
        sparse_bytes * 4 <= dense_bytes,
        "CSR broadcast should cost ≤25% of dense: sparse={sparse_bytes} dense={dense_bytes}"
    );
}

#[test]
fn shared_blocks_survive_blockify_roundtrip_in_both_formats() {
    // Whole-block selection shares the source blocks (an Arc bump, no
    // copy and no nnz rescan), for dense and CSR blocks alike.
    let cluster = Cluster::new(2, 64);
    let x = mixed(128, 128, 131);
    let xb = cluster.blockify(&x).unwrap();
    let whole = ops::slice_blocked(&cluster, &xb, 0, 128, 0, 128).unwrap();
    assert_eq!(whole.nnz(), x.nnz());
    for i in 0..xb.block_rows() {
        for j in 0..xb.block_cols() {
            assert!(
                std::ptr::eq(xb.block(i, j), whole.block(i, j)),
                "block ({i},{j}) should be shared, not copied"
            );
        }
    }
}
