//! Thread-pool determinism gates (PR 6): every blocked operator must be
//! **byte-identical** between `threads = 1` (the serial escape hatch,
//! inline execution) and `threads = N` (the worker thread pool), because
//! all reductions fold driver-side in the serial iteration order. The
//! accounting (per-worker FLOPs, task counts, comm bytes) must also be
//! identical — tasks are recorded at dispatch, never inside pool
//! closures, so parallel execution can neither drop nor double-charge a
//! task. Includes a multi-driver stress test (parfor-style concurrent
//! batches against one shared pool) and script-level parity through the
//! `dist_threads` config knob.

use std::sync::Arc;

use systemml::api::{MLContext, Script};
use systemml::conf::SystemConfig;
use systemml::runtime::conv::ConvShape;
use systemml::runtime::dist::nn as dist_nn;
use systemml::runtime::dist::{ops, Cluster};
use systemml::runtime::matrix::agg::AggOp;
use systemml::runtime::matrix::elementwise::{BinOp, UnaryOp};
use systemml::runtime::matrix::randgen::{rand, Pdf};
use systemml::runtime::matrix::Matrix;
use systemml::util::metrics;

const BS: usize = 32;
const WORKERS: usize = 4;
const THREADS: usize = 4;

/// Serial (inline) and parallel (pool) clusters with the same topology:
/// same worker count, block size, and unbounded budgets — only the
/// execution backend differs, so every observable must match.
fn cluster_pair() -> (Cluster, Cluster) {
    (Cluster::with_threads(WORKERS, BS, 1), Cluster::with_threads(WORKERS, BS, THREADS))
}

/// Bit-exact view of a matrix (plain `==` on f64 is the wrong tool:
/// NaN != NaN and -0.0 == 0.0 would mask real divergence).
fn bits(m: &Matrix) -> Vec<u64> {
    m.to_row_major_vec().iter().map(|v| v.to_bits()).collect()
}

/// Run `f` on both clusters and assert the results and the full
/// accounting (tasks, per-worker FLOPs, comm bytes) are identical.
fn assert_op_deterministic(name: &str, f: impl Fn(&Cluster) -> Matrix) {
    let (serial, parallel) = cluster_pair();
    assert_eq!(serial.threads(), 1);
    assert_eq!(parallel.threads(), THREADS);
    let a = f(&serial);
    let b = f(&parallel);
    assert_eq!(bits(&a), bits(&b), "{name}: threads=1 vs threads={THREADS} diverged");
    assert_eq!(serial.tasks(), parallel.tasks(), "{name}: task counts diverged");
    assert_eq!(serial.worker_flops(), parallel.worker_flops(), "{name}: FLOP attribution diverged");
    assert_eq!(serial.comm_bytes(), parallel.comm_bytes(), "{name}: comm accounting diverged");
}

#[test]
fn matmult_blocked_is_deterministic() {
    // 3x3 @ 3x2 block grid: multi-block k-accumulation inside each task.
    let a = rand(96, 70, -1.0, 1.0, 1.0, Pdf::Uniform, 1).unwrap();
    let b = rand(70, 50, -1.0, 1.0, 0.4, Pdf::Uniform, 2).unwrap();
    assert_op_deterministic("matmult", |cl| {
        let ab = cl.blockify(&a).unwrap();
        let bb = cl.blockify(&b).unwrap();
        ops::matmult_blocked(cl, &ab, &bb).unwrap().to_local().unwrap()
    });
}

#[test]
fn cellwise_and_reorg_ops_are_deterministic() {
    let a = rand(80, 70, -2.0, 2.0, 0.8, Pdf::Uniform, 3).unwrap();
    let b = rand(80, 70, 0.5, 2.0, 1.0, Pdf::Uniform, 4).unwrap();
    for op in [BinOp::Add, BinOp::Mul, BinOp::Div, BinOp::Max] {
        assert_op_deterministic(&format!("binary {op:?}"), |cl| {
            let ab = cl.blockify(&a).unwrap();
            let bb = cl.blockify(&b).unwrap();
            ops::binary_blocked(cl, &ab, &bb, op).unwrap().to_local().unwrap()
        });
    }
    assert_op_deterministic("scalar", |cl| {
        let ab = cl.blockify(&a).unwrap();
        ops::scalar_blocked(cl, &ab, 3.5, BinOp::Sub, true).unwrap().to_local().unwrap()
    });
    for op in [UnaryOp::Exp, UnaryOp::Abs, UnaryOp::Sigmoid] {
        assert_op_deterministic(&format!("unary {op:?}"), |cl| {
            let ab = cl.blockify(&a).unwrap();
            ops::unary_blocked(cl, &ab, op).to_local().unwrap()
        });
    }
    assert_op_deterministic("transpose", |cl| {
        let ab = cl.blockify(&a).unwrap();
        ops::transpose_blocked(cl, &ab).to_local().unwrap()
    });
}

#[test]
fn aggregates_are_deterministic() {
    // Mixed magnitudes make f64 addition order-sensitive: if partials
    // folded in completion order instead of grid order, these would flip
    // low bits nondeterministically.
    let a = rand(96, 66, -1e6, 1e6, 0.9, Pdf::Uniform, 5).unwrap();
    for op in [AggOp::Sum, AggOp::Mean, AggOp::Min, AggOp::Max, AggOp::SumSq, AggOp::Prod] {
        assert_op_deterministic(&format!("full_agg {op:?}"), |cl| {
            let ab = cl.blockify(&a).unwrap();
            Matrix::filled(1, 1, ops::full_agg_blocked(cl, &ab, op))
        });
        assert_op_deterministic(&format!("row_agg {op:?}"), |cl| {
            let ab = cl.blockify(&a).unwrap();
            ops::row_agg_blocked(cl, &ab, op).unwrap()
        });
        assert_op_deterministic(&format!("col_agg {op:?}"), |cl| {
            let ab = cl.blockify(&a).unwrap();
            ops::col_agg_blocked(cl, &ab, op).unwrap()
        });
    }
}

#[test]
fn indexing_ops_are_deterministic() {
    let a = rand(100, 90, -1.0, 1.0, 0.7, Pdf::Uniform, 6).unwrap();
    let patch = rand(40, 30, -1.0, 1.0, 1.0, Pdf::Uniform, 7).unwrap();
    // Aligned selection (origin on a block boundary) and a straddling
    // gather (origin mid-block, region crossing boundaries).
    for (name, (rl, ru, cl_, cu)) in
        [("slice aligned", (32, 96, 0, 64)), ("slice straddling", (17, 83, 9, 77))]
    {
        assert_op_deterministic(name, |cl| {
            let ab = cl.blockify(&a).unwrap();
            ops::slice_blocked(cl, &ab, rl, ru, cl_, cu).unwrap().to_local().unwrap()
        });
    }
    assert_op_deterministic("left_index", |cl| {
        let ab = cl.blockify(&a).unwrap();
        ops::left_index_blocked(cl, &ab, 25, 41, &patch, false).unwrap().to_local().unwrap()
    });
    assert_op_deterministic("left_index_fill", |cl| {
        let ab = cl.blockify(&a).unwrap();
        ops::left_index_fill_blocked(cl, &ab, 10, 70, 5, 65, 7.25).unwrap().to_local().unwrap()
    });
}

#[test]
fn broadcast_join_and_row_index_max_are_deterministic() {
    let a = rand(96, 64, -3.0, 3.0, 0.8, Pdf::Uniform, 8).unwrap();
    let col = rand(96, 1, 0.5, 2.0, 1.0, Pdf::Uniform, 9).unwrap();
    let row = rand(1, 64, 0.5, 2.0, 1.0, Pdf::Uniform, 10).unwrap();
    assert_op_deterministic("broadcast col-vector", |cl| {
        let ab = cl.blockify(&a).unwrap();
        ops::binary_broadcast_blocked(cl, &ab, &col, BinOp::Div, false)
            .unwrap()
            .to_local()
            .unwrap()
    });
    assert_op_deterministic("broadcast row-vector", |cl| {
        let ab = cl.blockify(&a).unwrap();
        ops::binary_broadcast_blocked(cl, &ab, &row, BinOp::Sub, false)
            .unwrap()
            .to_local()
            .unwrap()
    });
    // rowIndexMax with ties, NaNs in leading/trailing blocks, and an
    // all-NaN row: the parallel candidate fold must reproduce the CP
    // scan's NaN-sticky, leftmost-winner semantics exactly.
    let mut d = a.to_dense();
    for j in 0..64 {
        d.data[3 * 64 + j] = f64::NAN; // all-NaN row
    }
    d.data[7 * 64 + 2] = f64::NAN; // NaN in block column 0
    d.data[11 * 64 + 50] = f64::NAN; // NaN in block column 1
    d.data[20 * 64 + 5] = 9.0; // tie across block columns:
    d.data[20 * 64 + 40] = 9.0; // leftmost must win
    let nan_matrix = Matrix::Dense(d);
    assert_op_deterministic("rowIndexMax", |cl| {
        let ab = cl.blockify(&nan_matrix).unwrap();
        ops::row_index_max_blocked(cl, &ab).unwrap()
    });
}

#[test]
fn conv_and_pool_ops_are_deterministic() {
    // 96 images of 2x6x5 over 32-row blocks: three bands per batch.
    let conv_sh = ConvShape {
        c: 2,
        h: 6,
        w: 5,
        k: 3,
        r: 3,
        s: 2,
        stride: (2, 1),
        pad: (1, 1),
    };
    let pool_sh =
        ConvShape { c: 2, h: 6, w: 5, k: 2, r: 2, s: 2, stride: (2, 2), pad: (0, 0) };
    let x = rand(96, 60, -1.0, 1.0, 0.7, Pdf::Uniform, 20).unwrap();
    let w = rand(3, 12, -1.0, 1.0, 1.0, Pdf::Uniform, 21).unwrap();
    let dconv = rand(96, 54, -1.0, 1.0, 1.0, Pdf::Uniform, 22).unwrap();
    let dpool = rand(96, 12, -1.0, 1.0, 1.0, Pdf::Uniform, 23).unwrap();
    let bias = rand(3, 1, -1.0, 1.0, 1.0, Pdf::Uniform, 24).unwrap();
    assert_op_deterministic("conv2d", |cl| {
        let xb = cl.blockify(&x).unwrap();
        dist_nn::conv2d_blocked(cl, &xb, &w, &conv_sh, false).unwrap().to_local().unwrap()
    });
    assert_op_deterministic("conv2d_backward_data", |cl| {
        let db = cl.blockify(&dconv).unwrap();
        dist_nn::conv2d_backward_data_blocked(cl, &w, &db, &conv_sh, false)
            .unwrap()
            .to_local()
            .unwrap()
    });
    // Multi-band filter gradient: the per-band partials MUST fold in
    // band order for this to hold bitwise.
    assert_op_deterministic("conv2d_backward_filter", |cl| {
        let xb = cl.blockify(&x).unwrap();
        let db = cl.blockify(&dconv).unwrap();
        dist_nn::conv2d_backward_filter_blocked(cl, &xb, &db, &conv_sh).unwrap()
    });
    assert_op_deterministic("max_pool", |cl| {
        let xb = cl.blockify(&x).unwrap();
        dist_nn::max_pool_blocked(cl, &xb, &pool_sh).unwrap().to_local().unwrap()
    });
    assert_op_deterministic("avg_pool", |cl| {
        let xb = cl.blockify(&x).unwrap();
        dist_nn::avg_pool_blocked(cl, &xb, &pool_sh).unwrap().to_local().unwrap()
    });
    assert_op_deterministic("max_pool_backward", |cl| {
        let xb = cl.blockify(&x).unwrap();
        let db = cl.blockify(&dpool).unwrap();
        dist_nn::max_pool_backward_blocked(cl, &xb, &db, &pool_sh).unwrap().to_local().unwrap()
    });
    assert_op_deterministic("avg_pool_backward", |cl| {
        let xb = cl.blockify(&x).unwrap();
        let db = cl.blockify(&dpool).unwrap();
        dist_nn::avg_pool_backward_blocked(cl, &xb, &db, &pool_sh).unwrap().to_local().unwrap()
    });
    assert_op_deterministic("bias_add", |cl| {
        let cb = cl.blockify(&dconv).unwrap();
        dist_nn::bias_op_blocked(cl, &cb, &bias, 3, false, false).unwrap().to_local().unwrap()
    });
}

/// Serial clusters must execute tasks inline on the calling thread (the
/// escape hatch really is serial); parallel clusters must run them on
/// pool threads and bump the pool metrics.
#[test]
fn serial_escape_hatch_runs_inline() {
    let (serial, parallel) = cluster_pair();
    let a = rand(70, 70, -1.0, 1.0, 1.0, Pdf::Uniform, 30).unwrap();
    let caller = std::thread::current().id();

    let before = metrics::global().snapshot();
    let ab = serial.blockify(&a).unwrap();
    ops::unary_blocked(&serial, &ab, UnaryOp::Abs);
    // Inline execution is observable through thread identity: a worker
    // thread would have a different id. Exercise it directly too.
    let ids = serial.run_tasks(vec![(
        0,
        Box::new(move || std::thread::current().id())
            as Box<dyn FnOnce() -> std::thread::ThreadId + Send>,
    )]);
    assert_eq!(ids[0], caller, "threads=1 must execute on the calling thread");

    // Pool batches are monotonic and global; the parallel run must add
    // at least its own block count (other tests may add more — only
    // lower-bound the delta).
    let ab = parallel.blockify(&a).unwrap();
    ops::unary_blocked(&parallel, &ab, UnaryOp::Abs);
    let after = metrics::global().snapshot();
    let blocks = (ab.block_rows() * ab.block_cols()) as u64;
    assert!(
        after.pool_tasks >= before.pool_tasks + blocks,
        "parallel run must execute {blocks} blocks on the pool"
    );
    let ids = parallel.run_tasks(vec![(
        0,
        Box::new(move || std::thread::current().id())
            as Box<dyn FnOnce() -> std::thread::ThreadId + Send>,
    )]);
    assert_ne!(ids[0], caller, "threads={THREADS} must execute on a pool thread");
}

/// Stress: many driver threads (the parfor pattern) issue DIST matmults
/// against ONE shared cluster concurrently. Must not deadlock, every
/// result must be correct, and the per-cluster task counter must land on
/// the exact serial total — proof that accounting is neither dropped nor
/// double-charged under contention.
#[test]
fn concurrent_drivers_share_one_pool() {
    const DRIVERS: usize = 8;
    const REPS: usize = 6;
    let cluster = Arc::new(Cluster::with_threads(WORKERS, BS, THREADS));
    let a = rand(96, 70, -1.0, 1.0, 1.0, Pdf::Uniform, 31).unwrap();
    let b = rand(70, 50, -1.0, 1.0, 1.0, Pdf::Uniform, 32).unwrap();
    let expect = {
        let serial = Cluster::with_threads(WORKERS, BS, 1);
        let ab = serial.blockify(&a).unwrap();
        let bb = serial.blockify(&b).unwrap();
        let out = ops::matmult_blocked(&serial, &ab, &bb).unwrap().to_local().unwrap();
        (bits(&out), serial.tasks())
    };
    let ab = cluster.blockify(&a).unwrap();
    let bb = cluster.blockify(&b).unwrap();
    let base_tasks = cluster.tasks();
    std::thread::scope(|s| {
        for _ in 0..DRIVERS {
            let cluster = Arc::clone(&cluster);
            let (ab, bb) = (ab.clone(), bb.clone());
            let expect_bits = expect.0.clone();
            s.spawn(move || {
                for _ in 0..REPS {
                    let out =
                        ops::matmult_blocked(&cluster, &ab, &bb).unwrap().to_local().unwrap();
                    assert_eq!(bits(&out), expect_bits, "concurrent result diverged");
                }
            });
        }
    });
    assert_eq!(
        cluster.tasks() - base_tasks,
        expect.1 * (DRIVERS * REPS) as u64,
        "task accounting must be exact under concurrent drivers"
    );
}

/// Script-level parity through the public config knob: the same program
/// (mini-batch loop with DIST matmult, slicing, aggregates, and a parfor
/// whose bodies issue DIST ops) is byte-identical under `dist_threads=1`
/// and `dist_threads=4` — and the parfor+DIST combination completes
/// (scoped driver threads submitting pool batches must not deadlock).
#[test]
fn scripts_match_bitwise_across_thread_counts() {
    let src = "acc = matrix(0, rows=8, cols=1)\n\
               parfor (i in 1:8) {\n\
                 beg = (i - 1) * 16 + 1\n\
                 fin = i * 16\n\
                 Xi = X[beg:fin, ]\n\
                 S = Xi %*% W\n\
                 acc[i, ] = sum(S ^ 2)\n\
               }\n\
               Z = X %*% W\n\
               total = sum(Z) + sum(acc)";
    let x = rand(128, 96, -1.0, 1.0, 0.9, Pdf::Uniform, 40).unwrap();
    let w = rand(96, 48, -1.0, 1.0, 1.0, Pdf::Uniform, 41).unwrap();
    let run = |threads: usize| {
        let mut config = SystemConfig::tiny_driver(16 * 1024);
        config.block_size = BS;
        config.num_workers = WORKERS;
        config.dist_threads = threads;
        let script = Script::from_str(src)
            .input("X", x.clone())
            .input("W", w.clone())
            .output("acc")
            .output("total");
        let ctx = MLContext::with_config(config);
        ctx.execute(script).expect("script run")
    };
    let serial = run(1);
    let parallel = run(4);
    assert_eq!(
        bits(&serial.matrix("acc").unwrap()),
        bits(&parallel.matrix("acc").unwrap()),
        "parfor-accumulated DIST results diverged across thread counts"
    );
    assert_eq!(
        serial.double("total").unwrap().to_bits(),
        parallel.double("total").unwrap().to_bits(),
        "script output diverged across thread counts"
    );
}
