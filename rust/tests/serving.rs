//! Scoring-as-a-service gates (PR 9): the dynamic micro-batcher flushes
//! on whichever of the size/wait bounds hits first; a warm service with
//! resident weights scores every micro-batch with **zero driver
//! collects** and compiles once per distinct padded batch geometry; and
//! the batched blocked forward pass is **byte-identical** to a
//! one-row-at-a-time CP reference — across `dist_threads` 1 vs 4 and
//! with several micro-batches in flight concurrently.

use systemml::api::{MLContext, Script};
use systemml::conf::SystemConfig;
use systemml::runtime::matrix::randgen::{rand, Pdf};
use systemml::runtime::matrix::Matrix;
use systemml::runtime::serve::batcher::{FlushReason, MicroBatcher, ScoreRequest};
use systemml::runtime::serve::{run_simulation, ScoreService};

/// Two-layer MLP forward pass. Every model dimension fits one 32-wide
/// block, so each matmult has a single k-block — no partial-sum
/// reassociation — which is what makes batched blocked scores
/// bit-comparable to the single-row CP reference.
const SERVE_SRC: &str = "H = max(X %*% W1 + b1, 0)\n\
                         S = H %*% W2 + b2";

const FEATURES: usize = 12;
const HIDDEN: usize = 16;
const CLASSES: usize = 4;

fn weights() -> Vec<(&'static str, Matrix)> {
    vec![
        ("W1", rand(FEATURES, HIDDEN, -0.5, 0.5, 1.0, Pdf::Uniform, 41).unwrap()),
        ("b1", rand(1, HIDDEN, -0.1, 0.1, 1.0, Pdf::Uniform, 42).unwrap()),
        ("W2", rand(HIDDEN, CLASSES, -0.5, 0.5, 1.0, Pdf::Uniform, 43).unwrap()),
        ("b2", rand(1, CLASSES, -0.1, 0.1, 1.0, Pdf::Uniform, 44).unwrap()),
    ]
}

fn scoring_script() -> Script {
    let mut s = Script::from_str(SERVE_SRC).output("S");
    for (name, m) in weights() {
        s = s.input(name, m);
    }
    s
}

fn serve_config(threads: usize) -> SystemConfig {
    SystemConfig::builder()
        .driver_memory(8 * 1024)
        .block_size(32)
        .num_workers(4)
        .dist_threads(threads)
        .serve_max_batch(64)
        .serve_max_wait_ticks(8)
        .build()
}

fn service(threads: usize) -> (MLContext, ScoreService) {
    let ctx = MLContext::with_config(serve_config(threads));
    let svc = ctx.score_service(&scoring_script(), "X", FEATURES).unwrap();
    (ctx, svc)
}

/// One-row-at-a-time CP reference: a local-mode context (dist disabled)
/// scoring each request row as its own 1-row script execution.
fn cp_reference_scores(rows: &[Vec<f64>]) -> Vec<Vec<f64>> {
    let mut config = SystemConfig::default();
    config.dist_enabled = false;
    let ctx = MLContext::with_config(config);
    rows.iter()
        .map(|row| {
            let mut x = Matrix::zeros(1, FEATURES).into_dense_format();
            for (c, v) in row.iter().enumerate() {
                if let Matrix::Dense(d) = &mut x {
                    d.data[c] = *v;
                }
            }
            let script = scoring_script().input("X", x);
            let s = ctx.execute(script).unwrap().matrix("S").unwrap();
            (0..CLASSES).map(|c| s.get(0, c)).collect()
        })
        .collect()
}

fn bits(rows: &[Vec<f64>]) -> Vec<Vec<u64>> {
    rows.iter().map(|r| r.iter().map(|v| v.to_bits()).collect()).collect()
}

// ---- batcher bounds ------------------------------------------------------

fn req(id: u64, tick: u64) -> ScoreRequest {
    ScoreRequest { id, arrival_tick: tick, row: vec![1.0; FEATURES] }
}

#[test]
fn batcher_flushes_on_size_bound() {
    let mut b = MicroBatcher::from_config(&serve_config(1));
    for i in 0..65 {
        b.admit(req(i, 5));
    }
    let batch = b.poll(5).expect("size bound hit");
    assert_eq!(batch.reason, FlushReason::Size);
    assert_eq!(batch.requests.len(), 64);
    assert_eq!(batch.flush_tick, 5);
    // The 65th request waits for more arrivals or the wait bound.
    assert!(b.poll(5).is_none());
    assert_eq!(b.pending(), 1);
}

#[test]
fn batcher_flushes_on_wait_bound() {
    let mut b = MicroBatcher::from_config(&serve_config(1));
    b.admit(req(0, 10));
    b.admit(req(1, 14));
    assert!(b.poll(17).is_none(), "oldest has waited 7 < 8 ticks");
    let batch = b.poll(18).expect("wait bound hit");
    assert_eq!(batch.reason, FlushReason::Wait);
    assert_eq!(batch.requests.len(), 2, "a wait flush takes the whole partial queue");
    assert_eq!(batch.latencies(), vec![8, 4]);
}

#[test]
fn batcher_drains_partial_final_batch() {
    let mut b = MicroBatcher::from_config(&serve_config(1));
    for i in 0..3 {
        b.admit(req(i, 100));
    }
    assert!(b.poll(101).is_none(), "neither bound hit");
    let last = b.drain(101).expect("shutdown drain");
    assert_eq!(last.reason, FlushReason::Drain);
    assert_eq!(last.requests.len(), 3);
    assert_eq!(b.pending(), 0);
    assert!(b.drain(101).is_none());
}

// ---- scoring correctness -------------------------------------------------

#[test]
fn batched_scores_byte_identical_to_cp_one_row_reference() {
    let (_ctx, svc) = service(4);
    let report = run_simulation(&svc, 40, 7, 2, 1).unwrap();
    assert_eq!(report.scores.len(), 40);
    // Reconstruct the exact request rows the simulation generated (same
    // seeded arrival process) and score them one at a time in CP.
    let mut arrivals =
        systemml::runtime::serve::batcher::ArrivalProcess::new(7, FEATURES, 2);
    let rows: Vec<Vec<f64>> = (0..40).map(|_| arrivals.next_request().row).collect();
    let reference = cp_reference_scores(&rows);
    assert_eq!(
        bits(&report.scores),
        bits(&reference),
        "micro-batched blocked scores must be bit-equal to the 1-row CP reference"
    );
    // The wait bound (8 ticks) bounds every queueing latency.
    assert!(report.latency_ticks.iter().all(|&t| t <= 8));
    assert!(!report.flushes.is_empty());
}

#[test]
fn warm_service_scores_with_zero_collects() {
    let (ctx, svc) = service(4);
    let cluster = ctx.cluster().unwrap();
    // Warmup: compiles the plan for the padded geometry and touches
    // every weight handle once.
    let warm: Vec<Vec<f64>> = (0..5).map(|i| vec![0.5 + i as f64 * 0.01; FEATURES]).collect();
    svc.score_batch(&warm).unwrap();
    let compiles_after_warmup = svc.compile_count();
    assert_eq!(compiles_after_warmup, 1);

    cluster.reset_accounting();
    let report = run_simulation(&svc, 60, 3, 1, 1).unwrap();
    assert_eq!(report.scores.len(), 60);
    assert_eq!(
        cluster.collect_count(),
        0,
        "a warm service must never collect to the driver"
    );
    // The model broadcast happened at construction; warm batches move
    // only the batch blocks in and the response rows out.
    assert_eq!(svc.compile_count(), compiles_after_warmup, "no recompilation while warm");
    assert!(svc.rows_scored() >= 65);
}

#[test]
fn plans_cached_per_padded_geometry_not_per_request() {
    let (_ctx, svc) = service(1);
    assert_eq!(svc.padded_rows(1), 32);
    assert_eq!(svc.padded_rows(32), 32);
    assert_eq!(svc.padded_rows(33), 64);
    // Ten batches over two distinct padded geometries (32 and 64 rows).
    for n in [3usize, 10, 32, 5, 17, 40, 64, 33, 8, 50] {
        let rows: Vec<Vec<f64>> = (0..n).map(|i| vec![1.0 + i as f64 * 0.001; FEATURES]).collect();
        let out = svc.score_batch(&rows).unwrap();
        assert_eq!(out.len(), n);
        assert!(out.iter().all(|r| r.len() == CLASSES));
    }
    assert_eq!(svc.batch_count(), 10);
    assert_eq!(svc.compile_count(), 2, "one compile per distinct padded batch size");
}

#[test]
fn padding_does_not_leak_into_scores() {
    // The same request row must score bit-identically whether its batch
    // was full (64 → padded 64) or nearly empty (2 → padded 32): padding
    // rows are zero and the forward pass is row-independent.
    let (_ctx, svc) = service(1);
    let row: Vec<f64> = (0..FEATURES).map(|c| 0.75 + c as f64 * 0.05).collect();
    let small = svc.score_batch(std::slice::from_ref(&row)).unwrap();
    let mut big_rows = vec![vec![0.9; FEATURES]; 63];
    big_rows.insert(7, row);
    let big = svc.score_batch(&big_rows).unwrap();
    assert_eq!(bits(&small), bits(&[big[7].clone()]));
}

// ---- determinism ---------------------------------------------------------

#[test]
fn deterministic_across_thread_counts_and_inflight_batches() {
    let (_ctx, serial) = service(1);
    let (_ctx2, threaded) = service(4);
    let a = run_simulation(&serial, 80, 99, 2, 1).unwrap();
    // 4 pool threads AND 3 micro-batches in flight concurrently.
    let b = run_simulation(&threaded, 80, 99, 2, 3).unwrap();
    assert_eq!(a.latency_ticks, b.latency_ticks, "batch composition is seed-determined");
    assert_eq!(
        a.flushes.iter().map(|(n, _)| *n).collect::<Vec<_>>(),
        b.flushes.iter().map(|(n, _)| *n).collect::<Vec<_>>()
    );
    assert_eq!(
        bits(&a.scores),
        bits(&b.scores),
        "scores must be byte-identical across dist_threads 1 vs 4 and concurrent batches"
    );
}

#[test]
fn session_trained_weights_serve_without_rebroadcast() {
    // Train-then-serve on ONE session: the training script's blocked
    // outputs stay resident, and score_service picks them up from the
    // session without re-broadcasting the already-resident state.
    let ctx = MLContext::with_config(serve_config(4));
    let x = rand(96, FEATURES, -1.0, 1.0, 1.0, Pdf::Uniform, 51).unwrap();
    let y = rand(96, HIDDEN, -1.0, 1.0, 1.0, Pdf::Uniform, 52).unwrap();
    let w0 = rand(FEATURES, HIDDEN, -0.1, 0.1, 1.0, Pdf::Uniform, 53).unwrap();
    let train = Script::from_str(
        "for (e in 1:2) {\n\
           R = X %*% W1 - Y\n\
           g = t(X) %*% R\n\
           W1 = W1 - 0.01 * g\n\
         }",
    )
    .input("X", x)
    .input("Y", y)
    .input("W1", w0)
    .output("W1");
    ctx.execute(train).unwrap();

    // The scoring script reads the session-resident W1 plus fresh
    // driver-local second-layer weights.
    let score = Script::from_str("S = max(X %*% W1, 0) %*% W2")
        .input("W2", rand(HIDDEN, CLASSES, -0.5, 0.5, 1.0, Pdf::Uniform, 54).unwrap())
        .output("S");
    let svc = ctx.score_service(&score, "X", FEATURES).unwrap();
    let report = run_simulation(&svc, 30, 13, 2, 1).unwrap();
    assert_eq!(report.scores.len(), 30);
    assert_eq!(ctx.cluster().unwrap().collect_count(), 0, "train-then-serve never collects");
}
