//! Compiler-level tests: execution-type selection against the driver
//! budget, memory estimates, rewrites, and plan explanation.

use systemml::api::{MLContext, Script};
use systemml::conf::SystemConfig;
use systemml::hop::{estimate, rewrite};
use systemml::runtime::matrix::randgen::{rand, Pdf};
use systemml::runtime::matrix::Matrix;
use systemml::util::metrics;

/// Metric-delta tests serialize on this lock: the counters are
/// process-global and the test harness runs tests on multiple threads.
static METRICS_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn metrics_guard() -> std::sync::MutexGuard<'static, ()> {
    METRICS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[test]
fn cp_chosen_when_under_budget() {
    let _g = metrics_guard();
    let ctx = MLContext::new(); // default 512 MB driver
    let before = metrics::global().snapshot();
    let script = Script::from_str("Y = X %*% X\ns = sum(Y)")
        .input("X", Matrix::filled(64, 64, 1.0))
        .output("s");
    ctx.execute(script).unwrap();
    let d = metrics::global().snapshot().delta(&before);
    assert_eq!(d.dist_tasks, 0, "small matmult must stay CP");
}

#[test]
fn dist_chosen_when_over_budget_and_correct() {
    let _g = metrics_guard();
    let mut config = SystemConfig::tiny_driver(32 * 1024);
    config.block_size = 32;
    let ctx = MLContext::with_config(config);
    let before = metrics::global().snapshot();
    let x = rand(96, 96, -1.0, 1.0, 1.0, Pdf::Uniform, 3).unwrap();
    let script = Script::from_str("Y = X %*% X\ns = sum(Y)").input("X", x.clone()).output("Y");
    let res = ctx.execute(script).unwrap();
    let d = metrics::global().snapshot().delta(&before);
    assert!(d.dist_tasks > 0);
    // Cross-check numerics against CP.
    let cp = systemml::runtime::matrix::mult::matmult(&x, &x).unwrap();
    assert!(systemml::util::quickcheck::approx_eq_slice(
        &res.matrix("Y").unwrap().to_row_major_vec(),
        &cp.to_row_major_vec(),
        1e-9
    ));
}

#[test]
fn over_budget_without_dist_backend_errors() {
    let mut config = SystemConfig::tiny_driver(16 * 1024);
    config.dist_enabled = false;
    let ctx = MLContext::with_config(config);
    let script = Script::from_str("Y = X %*% X")
        .input("X", Matrix::filled(128, 128, 1.0))
        .output("Y");
    assert!(ctx.execute(script).is_err(), "local-only mode must refuse over-budget plans");
}

#[test]
fn sparsity_aware_estimates_keep_sparse_matmult_local() {
    let _g = metrics_guard();
    // A dense 400x400 matmult would blow a small budget, but at 1% density
    // the worst-case estimate keeps it CP (sparse operator).
    let budget = 900 * 1024; // 900 KB; dense would need ~3.8 MB
    let ctx = MLContext::with_config(SystemConfig::tiny_driver(budget));
    let x = rand(400, 400, -1.0, 1.0, 0.01, Pdf::Uniform, 4).unwrap();
    assert!(x.is_sparse());
    let before = metrics::global().snapshot();
    let script = Script::from_str("Y = X %*% X\ns = sum(Y)").input("X", x).output("s");
    ctx.execute(script).unwrap();
    let d = metrics::global().snapshot().delta(&before);
    assert_eq!(d.dist_tasks, 0, "sparse matmult should fit the driver budget");
}

#[test]
fn estimates_are_monotone_in_shape() {
    let small = estimate::estimate_size(100, 100, 1.0);
    let large = estimate::estimate_size(1000, 1000, 1.0);
    assert!(large > small * 50);
    let sp = estimate::estimate_size(1000, 1000, 0.01);
    assert!(sp < large / 10, "1% sparse estimate should be far below dense");
}

#[test]
fn constant_folding_observable_via_explain() {
    let ctx = MLContext::new();
    let script = Script::from_str("y = 2 * 3 + 1");
    let compiled = ctx.compile(&script).unwrap();
    let plan = systemml::hop::explain::explain_bundle(&compiled.bundle, &ctx.config);
    assert!(plan.contains("ASSIGN y <- 7"), "constant folding should appear in the plan:\n{plan}");
}

#[test]
fn matmult_chain_dp_agrees_with_bruteforce_small() {
    // Property: DP cost <= any left-to-right or right-to-left evaluation.
    let dims = [37, 91, 13, 64, 5];
    let (best, _) = rewrite::matmult_chain_order(&dims);
    let mut left = 0u64;
    for i in 1..dims.len() - 1 {
        left += 2 * (dims[0] * dims[i] * dims[i + 1]) as u64;
    }
    let mut right = 0u64;
    for i in (1..dims.len() - 1).rev() {
        right += 2 * (dims[0] * dims[i] * dims[i + 1]) as u64; // same formula shape
    }
    assert!(best <= left.min(right));
}

#[test]
fn explain_cli_shape() {
    let ctx = MLContext::new();
    let script = Script::from_str(
        "parfor (i in 1:4) { v = i }\nwhile (FALSE) { q = 1 }\nif (1 > 0) { a = 1 } else { a = 2 }",
    );
    let compiled = ctx.compile(&script).unwrap();
    let plan = systemml::hop::explain::explain_bundle(&compiled.bundle, &ctx.config);
    for needle in ["PARFOR i", "WHILE", "IF", "ELSE", "--MAIN (3 stmts)"] {
        assert!(plan.contains(needle), "missing {needle} in:\n{plan}");
    }
}
