//! Distributed NN-operator integration: CP-vs-blocked parity for all
//! seven conv/pool builtins (stride/pad variants, batches straddling
//! multiple row blocks, multi-column image grids), metadata-validated
//! error parity with zero collects, blocked bias ops, and the
//! LeNet-style training-epoch acceptance gate: a conv → pool → affine →
//! backward epoch over a blocked dataset runs with **zero driver
//! collects**, conv/pool outputs bound as `Value::Blocked`.

use std::sync::Arc;

use systemml::api::{MLContext, Script};
use systemml::conf::SystemConfig;
use systemml::runtime::interp::{Interpreter, Scope, Value};
use systemml::runtime::matrix::randgen::{rand, Pdf};
use systemml::util::quickcheck::approx_eq_slice;

/// Compile a script and run it on an inspectable interpreter.
fn run_inspectable(
    script: &Script,
    config: &SystemConfig,
) -> (Interpreter, Scope, systemml::hop::plan::Plan) {
    let ctx = MLContext::with_config(config.clone());
    let comp = ctx.compile(script).expect("compile");
    let plan = comp.plan.clone();
    let mut interp = Interpreter::new(comp.bundle, config.clone());
    interp.plan = Some(Arc::new(comp.plan));
    let inputs: Scope = script.inputs.clone().into_iter().collect();
    let out = interp.run(inputs).expect("run");
    (interp, out, plan)
}

fn dist_config(budget: usize, block: usize) -> SystemConfig {
    let mut c = SystemConfig::tiny_driver(budget);
    c.block_size = block;
    c.num_workers = 4;
    c
}

/// All seven builtins, CP vs blocked, over 2x6x5 images with stride/pad
/// variants. The 96-image batch spans three 32-row blocks and the 60
/// image columns span two 32-column blocks, so both the multi-band and
/// the band-assembly (multi-column) paths are exercised. Everything
/// except the multi-band `conv2d_backward_filter` fold must be
/// byte-identical (per-image kernels); the filter gradient matches to
/// 1e-9 (per-band partials fold at the driver — summation order).
#[test]
fn conv_builtin_parity_cp_vs_blocked() {
    let src = "C1 = conv2d(X, W, input_shape=[96,2,6,5], filter_shape=[3,2,3,2], stride=[2,1], padding=[1,1])\n\
               Cb = bias_add(C1, bvec)\n\
               dX = conv2d_backward_data(W, dC, input_shape=[96,2,6,5], filter_shape=[3,2,3,2], stride=[2,1], padding=[1,1])\n\
               dW = conv2d_backward_filter(X, dC, input_shape=[96,2,6,5], filter_shape=[3,2,3,2], stride=[2,1], padding=[1,1])\n\
               P1 = max_pool(X, input_shape=[96,2,6,5], pool_size=[2,2], stride=[2,2], padding=[0,0])\n\
               P2 = avg_pool(X, input_shape=[96,2,6,5], pool_size=[3,3], stride=[1,2], padding=[1,1])\n\
               dP1 = max_pool_backward(X, dP, input_shape=[96,2,6,5], pool_size=[2,2], stride=[2,2], padding=[0,0])\n\
               dP2 = avg_pool_backward(X, dQ, input_shape=[96,2,6,5], pool_size=[3,3], stride=[1,2], padding=[1,1])";
    let x = rand(96, 60, -1.0, 1.0, 0.6, Pdf::Uniform, 70).unwrap();
    let w = rand(3, 12, -1.0, 1.0, 1.0, Pdf::Uniform, 71).unwrap();
    let bvec = rand(3, 1, -1.0, 1.0, 1.0, Pdf::Uniform, 72).unwrap();
    // conv output: p=3, q=6 → K*P*Q = 54; max_pool: c*p*q = 12;
    // avg_pool: c*p*q = 36.
    let dc = rand(96, 54, -1.0, 1.0, 1.0, Pdf::Uniform, 73).unwrap();
    let dp = rand(96, 12, -1.0, 1.0, 1.0, Pdf::Uniform, 74).unwrap();
    let dq = rand(96, 36, -1.0, 1.0, 1.0, Pdf::Uniform, 75).unwrap();
    let outputs = ["C1", "Cb", "dX", "dW", "P1", "P2", "dP1", "dP2"];
    let run = |budget: usize, explain: bool| {
        let mut config = dist_config(budget, 32);
        config.explain = explain;
        let mut script = Script::from_str(src)
            .input("X", x.clone())
            .input("W", w.clone())
            .input("bvec", bvec.clone())
            .input("dC", dc.clone())
            .input("dP", dp.clone())
            .input("dQ", dq.clone());
        for o in outputs {
            script = script.output(o);
        }
        run_inspectable(&script, &config)
    };
    let (cp_interp, cp_out, _) = run(512 * 1024 * 1024, false);
    let (dist_interp, dist_out, plan) = run(16 * 1024, true);
    assert_eq!(cp_interp.cluster.as_ref().unwrap().blockify_count(), 0, "huge budget stays CP");
    let cluster = dist_interp.cluster.as_ref().unwrap();
    assert!(cluster.tasks() > 0, "tiny budget must run the conv ops DIST");
    // Blocked bindings: batch-shaped outputs stay distributed; the
    // filter gradient returns with the job as a driver matrix.
    for name in ["C1", "Cb", "dX", "P1", "P2", "dP1", "dP2"] {
        assert!(
            matches!(dist_out.get(name), Some(Value::Blocked(_))),
            "{name} must bind blocked: {:?}",
            dist_out.get(name)
        );
    }
    assert!(
        matches!(dist_out.get("dW"), Some(Value::Matrix(_))),
        "dW returns with the job: {:?}",
        dist_out.get("dW")
    );
    // The planner placed and annotated the conv operators.
    assert!(plan.render().contains(" CONV"), "{}", plan.render());
    assert!(
        dist_interp.output().iter().any(|l| l.contains("EXPLAIN: CONV")),
        "runtime EXPLAIN must surface the banded conv dispatch"
    );
    // Parity (forcing the blocked outputs counts collects — checked
    // after the zero-collect assertions in the epoch test below).
    for name in outputs {
        let a = cp_out.get(name).unwrap().as_matrix().unwrap().to_row_major_vec();
        let b = dist_out.get(name).unwrap().as_matrix().unwrap().to_row_major_vec();
        if name == "dW" {
            assert!(approx_eq_slice(&a, &b, 1e-9), "dW matches to summation order");
        } else {
            assert_eq!(a, b, "{name} must be byte-identical across CP and blocked");
        }
    }
}

/// Bugfix gate: two-operand conv/pool builtins validate *both* operands
/// — including the dout batch dimension — from handle metadata. A
/// blocked batch with a mismatched dout raises exactly the CP error with
/// zero collects (the CP kernels used to discover this only after a
/// force; narrow filters used to panic conv2d_backward_data outright).
#[test]
fn blocked_conv_shape_errors_match_cp_without_collect() {
    let x = rand(64, 64, -1.0, 1.0, 1.0, Pdf::Uniform, 76).unwrap();
    // Z = X %*% X is 64x64 and blocked under the tiny budget; 64 cols =
    // [1,8,8] images. max_pool 2x2 → dout should be 64x16.
    let cases = [
        // dout batch-dim mismatch (50 != 64).
        "E = max_pool_backward(Z, D, input_shape=[64,1,8,8], pool_size=[2,2], stride=[2,2], padding=[0,0])",
        // dout wrong width for the conv geometry.
        "E = conv2d_backward_filter(Z, D, input_shape=[64,1,8,8], filter_shape=[2,1,3,3], stride=[1,1], padding=[1,1])",
        // input width does not match C*H*W.
        "E = conv2d(Z, F, input_shape=[64,1,9,9], filter_shape=[2,1,3,3], stride=[1,1], padding=[1,1])",
    ];
    let d = rand(50, 16, -1.0, 1.0, 1.0, Pdf::Uniform, 77).unwrap();
    let f = rand(2, 9, -1.0, 1.0, 1.0, Pdf::Uniform, 78).unwrap();
    for case in cases {
        let src = format!("Z = X %*% X\n{case}");
        let run = |budget: usize| {
            let config = dist_config(budget, 32);
            let ctx = MLContext::with_config(config.clone());
            let script = Script::from_str(&src)
                .input("X", x.clone())
                .input("D", d.clone())
                .input("F", f.clone());
            let comp = ctx.compile(&script).expect("compile");
            let mut interp = Interpreter::new(comp.bundle, config.clone());
            interp.plan = Some(Arc::new(comp.plan));
            let inputs: Scope = script.inputs.clone().into_iter().collect();
            let err = interp.run(inputs).expect_err("bad geometry must fail");
            (interp, err.to_string())
        };
        let (_, cp_err) = run(512 * 1024 * 1024);
        let (dist_interp, dist_err) = run(16 * 1024);
        assert_eq!(cp_err, dist_err, "{case}");
        let cluster = dist_interp.cluster.as_ref().unwrap();
        assert_eq!(
            cluster.collect_count(),
            0,
            "{case}: metadata validation must not force the blocked batch"
        );
    }
}

/// Acceptance gate (the tentpole): a LeNet-style training epoch —
/// blocked `X[beg:end,]` batch → conv2d → max_pool → affine → loss →
/// affine backward → pool backward → conv filter gradient → driver-side
/// weight updates — runs entirely on the blocked backend with **zero
/// driver collects**, batches straddling two row blocks. CP and blocked
/// runs agree on the trained weights to summation order.
#[test]
fn lenet_epoch_runs_with_zero_collects() {
    let src = "nb = nrow(X) / bsize\n\
               for (e in 1:epochs) {\n\
                 for (b in 1:nb) {\n\
                   beg = (b - 1) * bsize + 1\n\
                   end = b * bsize\n\
                   Xb = X[beg:end, ]\n\
                   Yb = Y[beg:end, ]\n\
                   C1 = conv2d(Xb, W1, input_shape=[bsize,1,8,8], filter_shape=[4,1,3,3], stride=[1,1], padding=[1,1])\n\
                   H1 = max_pool(C1, input_shape=[bsize,4,8,8], pool_size=[2,2], stride=[2,2], padding=[0,0])\n\
                   P = H1 %*% W2\n\
                   dP = (P - Yb) / bsize\n\
                   dW2 = t(H1) %*% dP\n\
                   dH1 = dP %*% t(W2)\n\
                   dC1 = max_pool_backward(C1, dH1, input_shape=[bsize,4,8,8], pool_size=[2,2], stride=[2,2], padding=[0,0])\n\
                   dW1 = conv2d_backward_filter(Xb, dC1, input_shape=[bsize,1,8,8], filter_shape=[4,1,3,3], stride=[1,1], padding=[1,1])\n\
                   W1 = W1 - lr * dW1\n\
                   W2 = W2 - lr * dW2\n\
                 }\n\
               }\n\
               wsum = sum(W1 ^ 2) + sum(W2 ^ 2)";
    // 256 images of 1x8x8 over 64-blocks: each 128-image batch spans two
    // row blocks; block-aligned slice origins.
    let x = rand(256, 64, -1.0, 1.0, 1.0, Pdf::Uniform, 90).unwrap();
    let y = rand(256, 10, 0.0, 1.0, 1.0, Pdf::Uniform, 91).unwrap();
    let w1 = rand(4, 9, -0.5, 0.5, 1.0, Pdf::Uniform, 92).unwrap();
    let w2 = rand(64, 10, -0.5, 0.5, 1.0, Pdf::Uniform, 93).unwrap();
    let run = |budget: usize| {
        let config = dist_config(budget, 64);
        let script = Script::from_str(src)
            .input("X", x.clone())
            .input("Y", y.clone())
            .input("W1", w1.clone())
            .input("W2", w2.clone())
            .input_scalar("bsize", 128.0)
            .input_scalar("epochs", 2.0)
            .input_scalar("lr", 0.05)
            .output("wsum")
            .output("W1")
            .output("W2");
        run_inspectable(&script, &config)
    };
    let (cp_interp, cp_out, _) = run(512 * 1024 * 1024);
    let (dist_interp, dist_out, _) = run(32 * 1024);
    assert_eq!(cp_interp.cluster.as_ref().unwrap().blockify_count(), 0, "huge budget stays CP");
    let cluster = dist_interp.cluster.as_ref().unwrap();
    assert!(cluster.tasks() > 0, "the epoch must run on the blocked backend");
    // THE gate: nothing in the training loop may materialize a blocked
    // value at the driver — conv/pool outputs stay distributed, scalar
    // and K×CRS results return with their jobs.
    assert_eq!(cluster.collect_count(), 0, "LeNet epoch must run with zero driver collects");
    // Trained weights are driver values (single-block / job results).
    assert!(matches!(dist_out.get("W1"), Some(Value::Matrix(_))));
    assert!(matches!(dist_out.get("W2"), Some(Value::Matrix(_))));
    // Parity with the CP run, to summation order.
    for name in ["W1", "W2", "wsum"] {
        let a = match cp_out.get(name).unwrap() {
            v if v.is_matrix() => v.as_matrix().unwrap().to_row_major_vec(),
            v => vec![v.as_double().unwrap()],
        };
        let b = match dist_out.get(name).unwrap() {
            v if v.is_matrix() => v.as_matrix().unwrap().to_row_major_vec(),
            v => vec![v.as_double().unwrap()],
        };
        assert!(approx_eq_slice(&a, &b, 1e-9), "{name}: CP vs blocked epoch diverged");
    }
}
