//! Plan-level placement tests: the compiled HOP plan assigns ExecTypes
//! per operator, shrinking the driver budget flips matmult and aggregate
//! placements from CP to DIST, the runtime honors the placements, and
//! both plans produce numerically equivalent results (≤ 1e-9).

use systemml::api::{MLContext, Script};
use systemml::conf::SystemConfig;
use systemml::hop::plan::{ExecType, OpKind};
use systemml::runtime::matrix::randgen::{rand, Pdf};
use systemml::util::metrics;
use systemml::util::quickcheck::approx_eq_slice;

const SCRIPT: &str = "Y = X %*% X\nr = rowSums(Y)\ns = sum(Y)";

/// Tests that assert on global metric deltas serialize here — the
/// counters are process-global and the test harness is multi-threaded.
static METRICS_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn metrics_guard() -> std::sync::MutexGuard<'static, ()> {
    METRICS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn compile_with_budget(budget: usize) -> systemml::api::Compilation {
    let mut config = SystemConfig::tiny_driver(budget);
    config.block_size = 32;
    let ctx = MLContext::with_config(config);
    let x = rand(96, 96, -1.0, 1.0, 1.0, Pdf::Uniform, 42).unwrap();
    let script = Script::from_str(SCRIPT).input("X", x);
    ctx.compile(&script).unwrap()
}

#[test]
fn shrinking_budget_flips_matmult_and_agg_to_dist() {
    // Generous budget: everything CP.
    let roomy = compile_with_budget(512 * 1024 * 1024);
    assert_eq!(roomy.plan.placed_execs(OpKind::MatMult), vec![ExecType::CP]);
    assert!(roomy
        .plan
        .placed_execs(OpKind::Agg)
        .iter()
        .all(|e| *e == ExecType::CP));

    // Tiny budget: the same operators flip to DIST.
    let tiny = compile_with_budget(32 * 1024);
    assert_eq!(tiny.plan.placed_execs(OpKind::MatMult), vec![ExecType::Dist]);
    let aggs = tiny.plan.placed_execs(OpKind::Agg);
    assert!(!aggs.is_empty());
    assert!(aggs.iter().all(|e| *e == ExecType::Dist), "{aggs:?}");
}

#[test]
fn flipped_plan_is_numerically_equivalent() {
    let _g = metrics_guard();
    let x = rand(96, 96, -1.0, 1.0, 1.0, Pdf::Uniform, 43).unwrap();
    let run = |budget: usize| {
        let mut config = SystemConfig::tiny_driver(budget);
        config.block_size = 32;
        let ctx = MLContext::with_config(config);
        let script = Script::from_str(SCRIPT)
            .input("X", x.clone())
            .output("Y")
            .output("r")
            .output("s");
        let before = metrics::global().snapshot();
        let res = ctx.execute(script).unwrap();
        let tasks = metrics::global().snapshot().delta(&before).dist_tasks;
        (res, tasks)
    };
    let (cp, cp_tasks) = run(512 * 1024 * 1024);
    let (dist, dist_tasks) = run(32 * 1024);
    assert_eq!(cp_tasks, 0, "roomy budget must stay CP");
    assert!(dist_tasks > 0, "tiny budget must run distributed");
    assert!(approx_eq_slice(
        &cp.matrix("Y").unwrap().to_row_major_vec(),
        &dist.matrix("Y").unwrap().to_row_major_vec(),
        1e-9
    ));
    assert!(approx_eq_slice(
        &cp.matrix("r").unwrap().to_row_major_vec(),
        &dist.matrix("r").unwrap().to_row_major_vec(),
        1e-9
    ));
    let (s1, s2) = (cp.double("s").unwrap(), dist.double("s").unwrap());
    assert!((s1 - s2).abs() <= 1e-9 * s1.abs().max(1.0), "{s1} vs {s2}");
}

#[test]
fn explain_prints_hop_plan_with_exec_types() {
    let _g = metrics_guard();
    let mut config = SystemConfig::tiny_driver(32 * 1024);
    config.block_size = 32;
    config.explain = true;
    let ctx = MLContext::with_config(config);
    let x = rand(96, 96, -1.0, 1.0, 1.0, Pdf::Uniform, 44).unwrap();
    let script = Script::from_str(SCRIPT).input("X", x).output("s");
    let res = ctx.execute(script).unwrap();
    let out = res.stdout.join("\n");
    assert!(out.contains("# HOP PLAN"), "{out}");
    assert!(out.contains("ba(%*%)"), "{out}");
    assert!(out.contains("-> DIST"), "{out}");
    // Runtime explain lines are symmetric: CP placements are reported
    // with estimate-vs-budget too (the 1x1-ish ops here stay CP).
    assert!(out.contains("EXPLAIN:"), "{out}");
}

#[test]
fn plan_render_annotates_each_heavy_operator() {
    let compiled = compile_with_budget(32 * 1024);
    let rendered = compiled.plan.render();
    for needle in ["# HOP PLAN", "read X", "ba(%*%)", "uar(sum)", "ua(sum)", "-> DIST", "est "] {
        assert!(rendered.contains(needle), "missing {needle:?} in:\n{rendered}");
    }
}

#[test]
fn unknown_shapes_fall_back_to_runtime_dispatch() {
    let _g = metrics_guard();
    // X is not bound at compile time -> no placements, but execution
    // still flips to DIST from runtime estimates.
    let mut config = SystemConfig::tiny_driver(32 * 1024);
    config.block_size = 32;
    let ctx = MLContext::with_config(config);
    let script = Script::from_str("X = rand(rows=n, cols=n, seed=7)\nY = X %*% X\ns = sum(Y)")
        .input_scalar("n", 96.0)
        .output("s");
    let before = metrics::global().snapshot();
    ctx.execute(script).unwrap();
    let d = metrics::global().snapshot().delta(&before);
    assert!(d.dist_tasks > 0, "runtime fallback must still distribute");
}
