//! Cross-cutting property tests (quickcheck-lite; `proptest` is not in the
//! offline registry — see DESIGN.md §Substitutions): algebraic identities
//! of the matrix runtime across physical formats, format-decision
//! invariants, and interpreter/runtime agreement.

use systemml::api::{MLContext, Script};
use systemml::runtime::matrix::agg::{self, AggOp};
use systemml::runtime::matrix::elementwise::{self, BinOp};
use systemml::runtime::matrix::randgen::{rand, Pdf};
use systemml::runtime::matrix::{mult, reorg, Matrix};
use systemml::util::prng::Prng;
use systemml::util::quickcheck::{approx_eq, approx_eq_slice, forall_sized};

fn random_matrix(rng: &mut Prng, size: usize) -> Matrix {
    let r = 1 + rng.next_usize(size.max(1));
    let c = 1 + rng.next_usize(size.max(1));
    let density = [1.0, 0.5, 0.1, 0.01][rng.next_usize(4)];
    rand(r, c, -3.0, 3.0, density, Pdf::Uniform, rng.next_u64()).unwrap()
}

#[test]
fn transpose_is_involutive_all_formats() {
    forall_sized("t(t(X)) == X", 40, 120, random_matrix, |m| {
        let tt = reorg::transpose(&reorg::transpose(m));
        tt == *m
    });
}

#[test]
fn transpose_distributes_over_matmult() {
    forall_sized(
        "t(A%*%B) == t(B)%*%t(A)",
        20,
        50,
        |rng: &mut Prng, size| {
            let m = 1 + rng.next_usize(size.max(1));
            let k = 1 + rng.next_usize(size.max(1));
            let n = 1 + rng.next_usize(size.max(1));
            (
                rand(m, k, -2.0, 2.0, 0.6, Pdf::Uniform, rng.next_u64()).unwrap(),
                rand(k, n, -2.0, 2.0, 0.6, Pdf::Uniform, rng.next_u64()).unwrap(),
            )
        },
        |(a, b)| {
            let lhs = reorg::transpose(&mult::matmult(a, b).unwrap());
            let rhs =
                mult::matmult(&reorg::transpose(b), &reorg::transpose(a)).unwrap();
            approx_eq_slice(&lhs.to_row_major_vec(), &rhs.to_row_major_vec(), 1e-9)
        },
    );
}

#[test]
fn format_conversion_preserves_values_and_nnz() {
    forall_sized("format-roundtrip", 40, 150, random_matrix, |m| {
        let sparse = m.clone().into_sparse_format();
        let dense = sparse.clone().into_dense_format();
        dense == *m && sparse.nnz() == m.nnz() && sparse.sparsity() == m.sparsity()
    });
}

#[test]
fn elementwise_ops_agree_across_formats() {
    forall_sized(
        "cellop-format-agreement",
        24,
        60,
        |rng: &mut Prng, size| {
            let r = 1 + rng.next_usize(size.max(1));
            let c = 1 + rng.next_usize(size.max(1));
            (
                rand(r, c, -2.0, 2.0, 0.3, Pdf::Uniform, rng.next_u64()).unwrap(),
                rand(r, c, -2.0, 2.0, 0.3, Pdf::Uniform, rng.next_u64()).unwrap(),
            )
        },
        |(a, b)| {
            [BinOp::Add, BinOp::Sub, BinOp::Mul, BinOp::Max].iter().all(|op| {
                let dd = elementwise::binary(
                    &a.clone().into_dense_format(),
                    &b.clone().into_dense_format(),
                    *op,
                )
                .unwrap();
                let ss = elementwise::binary(
                    &a.clone().into_sparse_format(),
                    &b.clone().into_sparse_format(),
                    *op,
                )
                .unwrap();
                dd == ss
            })
        },
    );
}

#[test]
fn sum_linear_in_scalar_multiplication() {
    forall_sized("sum(c*X) == c*sum(X)", 30, 100, random_matrix, |m| {
        let c = 3.25;
        let scaled = elementwise::scalar_op(m, c, BinOp::Mul, false).unwrap();
        approx_eq(agg::full_agg(&scaled, AggOp::Sum), c * agg::full_agg(m, AggOp::Sum), 1e-9)
    });
}

#[test]
fn rowsums_then_sum_equals_total() {
    forall_sized("sum(rowSums(X)) == sum(X)", 30, 100, random_matrix, |m| {
        let rs = agg::row_agg(m, AggOp::Sum);
        approx_eq(agg::full_agg(&rs, AggOp::Sum), agg::full_agg(m, AggOp::Sum), 1e-9)
    });
}

#[test]
fn matmult_distributes_over_addition() {
    forall_sized(
        "A(B+C) == AB + AC",
        16,
        40,
        |rng: &mut Prng, size| {
            let m = 1 + rng.next_usize(size.max(1));
            let k = 1 + rng.next_usize(size.max(1));
            let n = 1 + rng.next_usize(size.max(1));
            (
                rand(m, k, -1.0, 1.0, 0.7, Pdf::Uniform, rng.next_u64()).unwrap(),
                rand(k, n, -1.0, 1.0, 0.7, Pdf::Uniform, rng.next_u64()).unwrap(),
                rand(k, n, -1.0, 1.0, 0.7, Pdf::Uniform, rng.next_u64()).unwrap(),
            )
        },
        |(a, b, c)| {
            let lhs =
                mult::matmult(a, &elementwise::binary(b, c, BinOp::Add).unwrap()).unwrap();
            let rhs = elementwise::binary(
                &mult::matmult(a, b).unwrap(),
                &mult::matmult(a, c).unwrap(),
                BinOp::Add,
            )
            .unwrap();
            approx_eq_slice(&lhs.to_row_major_vec(), &rhs.to_row_major_vec(), 1e-8)
        },
    );
}

#[test]
fn slicing_partition_reassembles() {
    forall_sized("rbind(X[1:k,], X[k+1:n,]) == X", 24, 80, random_matrix, |m| {
        if m.rows() < 2 {
            return true;
        }
        let k = m.rows() / 2;
        let top = reorg::slice(m, 0, k, 0, m.cols()).unwrap();
        let bottom = reorg::slice(m, k, m.rows(), 0, m.cols()).unwrap();
        reorg::rbind(&top, &bottom).unwrap() == *m
    });
}

#[test]
fn interpreter_matches_direct_runtime() {
    // Whole-pipeline property: a DML expression equals the same chain
    // composed directly against the runtime API.
    forall_sized(
        "dml == runtime",
        10,
        40,
        |rng: &mut Prng, size| {
            let n = 2 + rng.next_usize(size.max(1));
            rand(n, n, -1.0, 1.0, 0.8, Pdf::Uniform, rng.next_u64()).unwrap()
        },
        |x| {
            let ctx = MLContext::new();
            let script = Script::from_str("Y = t(X) %*% X + 1\ns = sum(Y * 2)")
                .input("X", x.clone())
                .output("s");
            let dml = ctx.execute(script).unwrap().double("s").unwrap();
            let y = elementwise::scalar_op(
                &mult::matmult(&reorg::transpose(x), x).unwrap(),
                1.0,
                BinOp::Add,
                false,
            )
            .unwrap();
            let direct =
                agg::full_agg(&elementwise::scalar_op(&y, 2.0, BinOp::Mul, false).unwrap(), AggOp::Sum);
            approx_eq(dml, direct, 1e-9)
        },
    );
}

#[test]
fn rand_sparsity_close_to_target() {
    forall_sized(
        "rand-sparsity",
        12,
        1,
        |rng: &mut Prng, _| {
            let target = [0.05, 0.2, 0.5, 0.9][rng.next_usize(4)];
            (target, rng.next_u64())
        },
        |(target, seed)| {
            let m = rand(120, 120, -1.0, 1.0, *target, Pdf::Uniform, *seed).unwrap();
            (m.sparsity() - target).abs() < 0.05
        },
    );
}
