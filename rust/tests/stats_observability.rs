//! Observability gates (PR 10): the SystemML `-stats`-style registry
//! must be deterministic where it claims to be — per-op counts and
//! communication bytes byte-identical across `dist_threads` settings —
//! report nothing (and cost nothing) when disabled, write a
//! well-formed JSON-lines trace with balanced span open/close pairs,
//! name the dominant matmult of an lm_cg loop in its heavy-hitter
//! table, and attribute serving latency so that queue + execute +
//! scatter accounts for every request exactly.

use std::collections::BTreeMap;

use systemml::api::{MLContext, Script};
use systemml::conf::SystemConfig;
use systemml::runtime::matrix::randgen::{rand, synthetic_classification, Pdf};
use systemml::runtime::matrix::reorg;
use systemml::runtime::serve::run_simulation;
use systemml::util::json::Json;

/// Conjugate-gradient loop on the normal equations: `t(X)` and `X` are
/// loop-invariant DIST operands, `p` rebinds every iteration. Matmult
/// invocations per run: 1 warmup (`t(X) %*% y`) + 3 per iteration.
const LM_CG: &str = r#"
w = matrix(0, rows=ncol(X), cols=1)
r = t(X) %*% y
p = r
norm_r2 = sum(r^2)
i = 0
while (i < max_iter) {
  i = i + 1
  q = t(X) %*% (X %*% p) + lambda * p
  alpha = norm_r2 / as.scalar(t(p) %*% q)
  w = w + alpha * p
  r = r - alpha * q
  old_norm = norm_r2
  norm_r2 = sum(r^2)
  p = r + (norm_r2 / old_norm) * p
}
final_norm = norm_r2
"#;

// X (400x64 doubles = 200 KB) exceeds the driver budget, so X-sized
// operators place DIST — same forcing as the dist bench.
fn stats_config(threads: usize) -> SystemConfig {
    SystemConfig::builder()
        .driver_memory(128 * 1024)
        .block_size(64)
        .num_workers(4)
        .dist_threads(threads)
        .cache_enabled(true)
        .stats_enabled(true)
        .build()
}

fn lm_cg_ctx(config: SystemConfig, iters: usize) -> MLContext {
    let (x, ylab) = synthetic_classification(400, 64, 4, 42);
    let y = reorg::slice(&ylab, 0, 400, 0, 1).unwrap();
    let ctx = MLContext::with_config(config);
    let script = Script::from_str(LM_CG)
        .input("X", x)
        .input("y", y)
        .input_scalar("lambda", 0.001)
        .input_scalar("max_iter", iters as f64)
        .output("final_norm");
    ctx.execute(script).expect("lm_cg run failed");
    ctx
}

/// The deterministic slice of the report: `(op, pos, exec)` keys with
/// their invocation counts and communication bytes. Wall time and
/// FLOPs-by-window are excluded by design (timings are exempt; the
/// FLOP counter is process-global, so parallel tests overlap it).
fn deterministic_rows(ctx: &MLContext) -> BTreeMap<(String, String, String), (u64, u64)> {
    ctx.stats()
        .expect("stats enabled")
        .ops
        .into_iter()
        .map(|o| ((o.op, o.pos, o.exec.to_string()), (o.count, o.comm_bytes)))
        .collect()
}

#[test]
fn op_counts_and_comm_identical_across_thread_counts() {
    let serial = lm_cg_ctx(stats_config(1), 5);
    let parallel = lm_cg_ctx(stats_config(4), 5);
    let a = deterministic_rows(&serial);
    let b = deterministic_rows(&parallel);
    assert!(!a.is_empty(), "stats-enabled run produced no operator rows");
    assert_eq!(
        a, b,
        "per-op counts/comm bytes diverged between dist_threads 1 and 4"
    );
}

#[test]
fn disabled_mode_reports_nothing() {
    let (x, ylab) = synthetic_classification(400, 64, 4, 42);
    let y = reorg::slice(&ylab, 0, 400, 0, 1).unwrap();
    let config = SystemConfig::builder()
        .driver_memory(128 * 1024)
        .block_size(64)
        .num_workers(4)
        .cache_enabled(true)
        .build();
    assert!(!config.stats_enabled, "stats must default to off");
    let ctx = MLContext::with_config(config);
    let script = Script::from_str(LM_CG)
        .input("X", x)
        .input("y", y)
        .input_scalar("lambda", 0.001)
        .input_scalar("max_iter", 2.0)
        .output("final_norm");
    ctx.execute(script).expect("lm_cg run failed");
    assert!(ctx.stats().is_none(), "disabled mode must expose no report");
    assert!(
        ctx.statistics().contains("disabled"),
        "disabled mode must say so: {}",
        ctx.statistics()
    );
}

#[test]
fn trace_is_json_lines_with_balanced_spans() {
    let path = std::env::temp_dir()
        .join(format!("systemml_stats_trace_{}.jsonl", std::process::id()));
    {
        let config = SystemConfig::builder()
            .driver_memory(128 * 1024)
            .block_size(64)
            .num_workers(4)
            .cache_enabled(true)
            .stats_enabled(true)
            .trace_path(&path)
            .build();
        // Session span closes when the context (the last `Stats` owner)
        // drops, so read the file only after this scope ends.
        let _ctx = lm_cg_ctx(config, 2);
    }
    let text = std::fs::read_to_string(&path).expect("trace file must exist");
    let _ = std::fs::remove_file(&path);
    let mut opens = 0u64;
    let mut closes = 0u64;
    let mut operator_spans = 0u64;
    let mut events = 0u64;
    let mut last_seq = 0u64;
    for line in text.lines().filter(|l| !l.trim().is_empty()) {
        let v = Json::parse(line).expect("every trace line must be valid JSON");
        let seq = v.get("seq").as_f64().expect("seq field") as u64;
        assert_eq!(seq, last_seq + 1, "seq must increase by 1 per record");
        last_seq = seq;
        match v.get("ev").as_str().expect("ev field") {
            "span_open" => opens += 1,
            "span_close" => {
                closes += 1;
                if v.get("kind").as_str() == Some("operator") {
                    operator_spans += 1;
                    assert!(v.get("bytes").as_f64().is_some(), "operator spans carry bytes");
                }
            }
            "event" => {
                events += 1;
                assert!(v.get("bytes").as_f64().is_some(), "events carry bytes");
            }
            other => panic!("unknown trace event kind: {other}"),
        }
    }
    assert!(opens > 0, "trace recorded no spans");
    assert_eq!(opens, closes, "span open/close records must balance");
    assert!(operator_spans > 0, "trace recorded no operator spans");
    assert!(events > 0, "trace recorded no blockify/broadcast/cache events");
}

#[test]
fn heavy_hitters_name_dominant_matmult() {
    let iters = 5u64;
    let ctx = lm_cg_ctx(stats_config(0), iters as usize);
    let report = ctx.stats().expect("stats enabled");
    // 1 warmup matmult + 3 per iteration, placement-independent.
    let ba_total: u64 = report
        .ops
        .iter()
        .filter(|o| o.op == "ba+*")
        .map(|o| o.count)
        .sum();
    assert_eq!(ba_total, 1 + 3 * iters, "unexpected matmult invocation count");
    assert!(
        report.ops.iter().any(|o| o.op == "ba+*" && o.exec == "DIST"),
        "the X-sized matmults must run on the blocked backend"
    );
    assert!(
        report.heavy_hitters(5).iter().any(|o| o.op == "ba+*"),
        "the loop's matmults must make the top-5 heavy hitters: {}",
        ctx.statistics()
    );
    assert!(report.skew_ratio.is_finite() && report.skew_ratio >= 1.0);
    assert!(
        report.workers.iter().any(|w| w.tasks > 0),
        "distributed work must stamp worker utilization slots"
    );
}

#[test]
fn serving_phases_account_for_every_request() {
    const FEATS: usize = 12;
    let config = SystemConfig::builder()
        .driver_memory(8 * 1024)
        .block_size(32)
        .num_workers(4)
        .serve_max_batch(64)
        .serve_max_wait_ticks(8)
        .build();
    let ctx = MLContext::with_config(config);
    let script = Script::from_str("S = X %*% W + b")
        .input("W", rand(FEATS, 4, -0.5, 0.5, 1.0, Pdf::Uniform, 41).unwrap())
        .input("b", rand(1, 4, -0.1, 0.1, 1.0, Pdf::Uniform, 42).unwrap())
        .output("S");
    let svc = ctx.score_service(&script, "X", FEATS).expect("score service");
    let requests = 64;
    let report = run_simulation(&svc, requests, 7, 3, 2).expect("simulation failed");
    assert_eq!(report.phases.len(), requests, "one phase split per request");
    for (i, p) in report.phases.iter().enumerate() {
        assert_eq!(
            p.exec_nanos + p.scatter_nanos,
            p.total_nanos,
            "request {i}: execute + scatter must sum to the batch total exactly"
        );
        assert!(p.total_nanos > 0, "request {i}: batch wall time cannot be zero");
        assert_eq!(
            p.queue_ticks, report.latency_ticks[i],
            "request {i}: queue wait must equal the simulated queueing latency"
        );
    }
}
