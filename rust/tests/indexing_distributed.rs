//! Distributed indexing + broadcast cellwise integration: CP-vs-DIST
//! parity for right-indexing (aligned / straddling / single-row / single
//! col), left-index write-then-read, broadcast cellwise (row vector, col
//! vector, 1x1 promotion), derived `X[..]#v` cache invalidation on
//! left-index writes, and the zero-collect acceptance gates for the
//! kmeans and mini-batch training loops.

use std::sync::Arc;

use systemml::api::{MLContext, Script};
use systemml::conf::SystemConfig;
use systemml::runtime::dist::cache::LineageRef;
use systemml::runtime::dist::{ops, Cluster};
use systemml::runtime::interp::{Interpreter, Scope, Value};
use systemml::runtime::matrix::randgen::{rand, Pdf};
use systemml::runtime::matrix::{mult, reorg, Matrix};
use systemml::util::quickcheck::approx_eq_slice;

/// Compile a script and run it on an inspectable interpreter.
fn run_inspectable(
    script: &Script,
    config: &SystemConfig,
) -> (Interpreter, Scope, systemml::hop::plan::Plan) {
    let ctx = MLContext::with_config(config.clone());
    let comp = ctx.compile(script).expect("compile");
    let plan = comp.plan.clone();
    let mut interp = Interpreter::new(comp.bundle, config.clone());
    interp.plan = Some(Arc::new(comp.plan));
    let inputs: Scope = script.inputs.clone().into_iter().collect();
    let out = interp.run(inputs).expect("run");
    (interp, out, plan)
}

fn dist_config(budget: usize, block: usize) -> SystemConfig {
    let mut c = SystemConfig::tiny_driver(budget);
    c.block_size = block;
    c.num_workers = 4;
    c
}

/// CP-vs-DIST right-index parity, byte-identical: slicing moves cells
/// without arithmetic, so a huge-driver (CP) run and a tiny-driver run
/// (X-sized slices placed DIST, outputs bound blocked) must agree
/// exactly — across an aligned batch slice, a straddling region, and
/// single-row / single-column selections.
#[test]
fn right_index_parity_cp_vs_dist() {
    let src = "B1 = X[1:32, ]\n\
               B2 = X[5:70, 3:40]\n\
               B3 = X[7, ]\n\
               B4 = X[, 9]";
    let x = rand(96, 96, -1.0, 1.0, 0.4, Pdf::Uniform, 80).unwrap();
    let run = |budget: usize| {
        let config = dist_config(budget, 32);
        let script = Script::from_str(src)
            .input("X", x.clone())
            .output("B1")
            .output("B2")
            .output("B3")
            .output("B4");
        run_inspectable(&script, &config)
    };
    let (cp_interp, cp_out, _) = run(512 * 1024 * 1024);
    let (dist_interp, dist_out, _) = run(16 * 1024);
    assert_eq!(cp_interp.cluster.as_ref().unwrap().blockify_count(), 0, "huge budget stays CP");
    let dc = dist_interp.cluster.as_ref().unwrap();
    assert!(dc.tasks() > 0, "tiny budget must run the slices DIST");
    for name in ["B1", "B2", "B3", "B4"] {
        let a = cp_out.get(name).unwrap().as_matrix().unwrap().to_row_major_vec();
        let b = dist_out.get(name).unwrap().as_matrix().unwrap().to_row_major_vec();
        assert_eq!(a, b, "{name} must be byte-identical across CP and DIST slicing");
    }
    // The aligned batch slice B1 (origin row 0/col 0 on 32-blocks) is
    // multi-block, so it binds as a first-class blocked value.
    assert!(
        matches!(dist_out.get("B1"), Some(Value::Blocked(_))),
        "aligned multi-block slice must stay blocked: {:?}",
        dist_out.get("B1")
    );
}

/// Bugfix gate: slicing a *blocked* value with out-of-range or reversed
/// bounds raises exactly the CP error, decided from handle metadata —
/// no force, no collect, no panic, no silent clamp.
#[test]
fn blocked_slice_bounds_errors_match_cp_without_collect() {
    let x = rand(96, 96, -1.0, 1.0, 1.0, Pdf::Uniform, 81).unwrap();
    let cases = ["B = Z[1:200, ]", "B = Z[5:2, ]", "B = Z[200, ]", "B = Z[, 97]"];
    for case in cases {
        let src = format!("Z = X %*% X\n{case}");
        // CP reference error (huge budget forces everything driver-side).
        let cp_err = {
            let config = dist_config(512 * 1024 * 1024, 32);
            let ctx = MLContext::with_config(config);
            let script = Script::from_str(src.clone()).input("X", x.clone()).output("B");
            ctx.execute(script).unwrap_err().to_string()
        };
        // DIST run: Z is a live blocked value when the slice fails.
        let config = dist_config(16 * 1024, 32);
        let ctx = MLContext::with_config(config.clone());
        let comp = ctx.compile(&Script::from_str(src.clone()).input("X", x.clone())).unwrap();
        let mut interp = Interpreter::new(comp.bundle, config);
        interp.plan = Some(Arc::new(comp.plan));
        let inputs: Scope = [("X".to_string(), Value::Matrix(x.clone()))].into_iter().collect();
        let dist_err = interp.run(inputs).unwrap_err().to_string();
        assert_eq!(cp_err, dist_err, "{case}: blocked bounds error must match CP");
        let cluster = interp.cluster.as_ref().unwrap();
        assert_eq!(
            cluster.collect_count(),
            0,
            "{case}: the failed slice must not force the blocked value"
        );
    }
}

/// Left-index write-then-read parity: a blocked target is rewritten
/// block-granularly on the cluster (it stays blocked, zero collects) and
/// reads back exactly the CP result.
#[test]
fn left_index_write_then_read_parity_and_stays_blocked() {
    let src = "Y = X %*% X\n\
               Y[3:10, 5:12] = P\n\
               Y[50, ] = X[1, ]\n\
               s = sum(Y)";
    let x = rand(96, 96, -1.0, 1.0, 1.0, Pdf::Uniform, 82).unwrap();
    let p = rand(8, 8, 5.0, 6.0, 1.0, Pdf::Uniform, 83).unwrap();
    let run = |budget: usize| {
        let config = dist_config(budget, 32);
        let script = Script::from_str(src)
            .input("X", x.clone())
            .input("P", p.clone())
            .output("Y")
            .output("s");
        run_inspectable(&script, &config)
    };
    let (_, cp_out, _) = run(512 * 1024 * 1024);
    let (dist_interp, dist_out, _) = run(16 * 1024);
    let cluster = dist_interp.cluster.as_ref().unwrap();
    // The writes and the aggregate all ran without materializing Y.
    assert_eq!(
        cluster.collect_count(),
        0,
        "left-index on a blocked target must not force it to the driver"
    );
    assert!(
        matches!(dist_out.get("Y"), Some(Value::Blocked(_))),
        "the written target must stay blocked: {:?}",
        dist_out.get("Y")
    );
    // Numerics: the matmult output differs only by block-partial
    // summation order; the written cells are byte-identical.
    let ya = cp_out.get("Y").unwrap().as_matrix().unwrap().to_row_major_vec();
    let yb = dist_out.get("Y").unwrap().as_matrix().unwrap().to_row_major_vec();
    assert!(approx_eq_slice(&ya, &yb, 1e-9));
    let (sa, sb) = (
        cp_out.get("s").unwrap().as_double().unwrap(),
        dist_out.get("s").unwrap().as_double().unwrap(),
    );
    assert!((sa - sb).abs() <= 1e-9 * sa.abs().max(1.0), "{sa} vs {sb}");
    // The written region reads back the patch exactly.
    let y = dist_out.get("Y").unwrap().as_matrix().unwrap().clone();
    assert_eq!(reorg::slice(&y, 2, 10, 4, 12).unwrap().to_row_major_vec(), p.to_row_major_vec());
}

/// Broadcast cellwise parity, byte-identical: row-vector, col-vector and
/// 1x1 rhs operands against a DIST-placed matrix produce exactly the CP
/// cells (the join applies the same per-cell kernel).
#[test]
fn broadcast_cellwise_parity_row_col_and_scalar_promotion() {
    let src = "mu = colMeans(X)\n\
               rs = rowSums(X ^ 2) + 1\n\
               one = matrix(3, rows=1, cols=1)\n\
               N1 = X - mu\n\
               N2 = X / rs\n\
               N3 = X * one\n\
               N4 = N1 * one";
    let x = rand(96, 96, -1.0, 1.0, 0.8, Pdf::Uniform, 84).unwrap();
    let run = |budget: usize| {
        let config = dist_config(budget, 32);
        let script = Script::from_str(src)
            .input("X", x.clone())
            .output("N1")
            .output("N2")
            .output("N3")
            .output("N4");
        run_inspectable(&script, &config)
    };
    let (cp_interp, cp_out, _) = run(512 * 1024 * 1024);
    let (dist_interp, dist_out, _) = run(16 * 1024);
    assert_eq!(cp_interp.cluster.as_ref().unwrap().blockify_count(), 0);
    let dc = dist_interp.cluster.as_ref().unwrap();
    assert!(dc.tasks() > 0, "tiny budget must distribute the broadcast pairs");
    // N4's 1x1 rhs promotes to a scalar map over N1's *blocked* output.
    for name in ["N1", "N2", "N3", "N4"] {
        let a = cp_out.get(name).unwrap().as_matrix().unwrap().to_row_major_vec();
        let b = dist_out.get(name).unwrap().as_matrix().unwrap().to_row_major_vec();
        assert_eq!(a, b, "{name} must be byte-identical across CP and broadcast join");
    }
    // A vector lhs is rejected identically on both paths (the CP kernel
    // only broadcasts rhs vectors).
    for budget in [512 * 1024 * 1024usize, 16 * 1024] {
        let config = dist_config(budget, 32);
        let ctx = MLContext::with_config(config);
        let script = Script::from_str("mu = colMeans(X)\nB = mu - X")
            .input("X", x.clone())
            .output("B");
        let err = ctx.execute(script).unwrap_err().to_string();
        assert!(err.contains("dimension mismatch"), "budget {budget}: {err}");
    }
}

/// Derived `X[..]#v` cache entries: created by a DIST slice of a driver
/// operand after a guarded hit on `X#v`, reused on the next identical
/// slice, and **invalidated by a left-index write** through the existing
/// derived-entry machinery (deps include the base variable).
#[test]
fn derived_slice_entries_reuse_and_invalidate_on_left_index_write() {
    // Unit-level: the cache drops a derived slice when its base is
    // invalidated (exactly what note_rebind does on a left-index write).
    let cl = Cluster::with_storage(2, 16, usize::MAX);
    let m = rand(48, 48, -1.0, 1.0, 1.0, Pdf::Uniform, 85).unwrap();
    let hx = LineageRef::var("X", 1);
    let (xb, _) = cl.cache().acquire(&cl, Some(&hx), &m).unwrap();
    let d = LineageRef::derived("X[1:16,1:48]".into(), 1, vec!["X".into()]);
    cl.cache().put_keyed(&d, Arc::new(ops::slice_blocked(&cl, &xb, 0, 16, 0, 48).unwrap()));
    assert!(cl.cache().resident_keyed(&d), "derived slice entry must be resident");
    cl.cache().invalidate("X");
    assert!(!cl.cache().resident_keyed(&d), "left-index write must drop derived slices");

    // Script-level: the same slice repeated hits the derived entry (no
    // extra blockify); a left-index write invalidates it and bumps the
    // lineage version, so the next slice re-partitions the new content.
    let src = "B1 = X[1:32, ]\n\
               B2 = X[1:32, ]\n\
               X[1:2, 1:2] = matrix(7, rows=2, cols=2)\n\
               B3 = X[1:32, ]\n\
               s = sum(B3)";
    let config = dist_config(16 * 1024, 32);
    let x = rand(96, 96, -1.0, 1.0, 1.0, Pdf::Uniform, 86).unwrap();
    let script = Script::from_str(src).input("X", x.clone()).output("B3").output("s");
    let (interp, out, _) = run_inspectable(&script, &config);
    let cluster = interp.cluster.as_ref().unwrap();
    let stats = cluster.cache().stats();
    assert!(
        stats.invalidations >= 1,
        "the left-index write must invalidate X's resident entries: {stats:?}"
    );
    // Blockifies: X exactly once (for B1; B2 reuses the derived slice
    // entry). The write rewrites the resident blocks — X becomes a
    // first-class blocked value, so B3 is a block selection of the
    // written handle, not a repartition.
    assert_eq!(
        cluster.blockify_count(),
        1,
        "derived slice reuse then blocked write (stats: {stats:?})"
    );
    // Correctness: B3 reflects the written cells.
    let b3 = out.get("B3").unwrap().as_matrix().unwrap().clone();
    assert_eq!(b3.get(0, 0), 7.0);
    assert_eq!(b3.get(1, 1), 7.0);
    let mut expected = reorg::left_index(&x, 0, 0, &Matrix::filled(2, 2, 7.0)).unwrap();
    expected = reorg::slice(&expected, 0, 32, 0, 96).unwrap();
    assert_eq!(b3.to_row_major_vec(), expected.to_row_major_vec());
}

/// Acceptance (tentpole, kmeans half): a full Lloyd's loop — slice-seeded
/// centroids, broadcast-cellwise distance line, blocked rowIndexMax —
/// performs **zero** driver collects across the whole run, and at most
/// the three freshly rebound driver intermediates repartition per
/// iteration.
#[test]
fn kmeans_loop_runs_zero_collects_per_iteration() {
    const ITERS: u64 = 5;
    let src = "C = X[1:k, ]\n\
               N = nrow(X)\n\
               for (it in 1:max_iter) {\n\
                 D2 = (-2) * (X %*% t(C)) + rowSums(X^2) + t(rowSums(C^2))\n\
                 assign = rowIndexMax(-D2)\n\
                 members = table(seq(1, N), assign, N, k)\n\
                 counts = colSums(members)\n\
                 C = (t(members) %*% X) / t(max(counts, 1))\n\
               }\n\
               D2 = (-2) * (X %*% t(C)) + rowSums(X^2) + t(rowSums(C^2))\n\
               wcss = sum(rowMins(D2))";
    let mut config = dist_config(32 * 1024, 48);
    config.explain = true;
    let x = rand(160, 48, -1.0, 1.0, 1.0, Pdf::Uniform, 87).unwrap();
    let script = Script::from_str(src)
        .input("X", x)
        .input_scalar("k", 4.0)
        .input_scalar("max_iter", ITERS as f64)
        .output("wcss");
    let (interp, out, _) = run_inspectable(&script, &config);
    let cluster = interp.cluster.as_ref().unwrap();
    assert_eq!(
        cluster.collect_count(),
        0,
        "kmeans must run zero-collect end-to-end (stats: {:?})",
        cluster.cache().stats()
    );
    // ≤ 3 repartitions per iteration: t(C), the anonymous X^2, and
    // t(members); warmup is X plus the final distance line's two.
    assert!(
        cluster.blockify_count() <= 3 * ITERS + 3,
        "kmeans blockify budget exceeded: {} > {}",
        cluster.blockify_count(),
        3 * ITERS + 3
    );
    assert!(out.get("wcss").unwrap().as_double().unwrap().is_finite());
    let explain = interp.output().join("\n");
    assert!(explain.contains("BCAST"), "broadcast joins must surface in EXPLAIN:\n{explain}");
    assert!(explain.contains("IDX"), "the seeding slice must surface in EXPLAIN:\n{explain}");
}

/// Acceptance (tentpole, mini-batch half): an epoch loop of block-aligned
/// batch slices → broadcast normalize → matmult → aggregate performs
/// zero driver collects; the only per-batch repartition is the freshly
/// rebound weight vector, and batch slices are pure block selections
/// reused across epochs through derived `X[..]#v` entries.
#[test]
fn minibatch_epoch_loop_runs_zero_collects_per_iteration() {
    const EPOCHS: u64 = 4;
    let src = "w = matrix(0.001, rows=ncol(X), cols=1)\n\
               mu = colMeans(X)\n\
               sigma = sqrt(colMeans(X^2) - mu^2) + 0.1\n\
               nb = nrow(X) / bsize\n\
               for (e in 1:max_iter) {\n\
                 for (b in 1:nb) {\n\
                   beg = (b - 1) * bsize + 1\n\
                   end = b * bsize\n\
                   Xb = X[beg:end, ]\n\
                   Xn = (Xb - mu) / sigma\n\
                   g = t(Xn) %*% (Xn %*% w)\n\
                   w = w - (0.01 / bsize) * g\n\
                 }\n\
               }\n\
               wnorm = sum(w ^ 2)";
    let mut config = dist_config(64 * 1024, 64);
    config.explain = true;
    let x = rand(256, 64, -1.0, 1.0, 1.0, Pdf::Uniform, 88).unwrap();
    let mk = |xm: Matrix| {
        Script::from_str(src)
            .input("X", xm)
            .input_scalar("bsize", 128.0)
            .input_scalar("max_iter", EPOCHS as f64)
            .output("w")
            .output("wnorm")
    };
    let (interp, out, _) = run_inspectable(&mk(x.clone()), &config);
    let cluster = interp.cluster.as_ref().unwrap();
    assert_eq!(
        cluster.collect_count(),
        0,
        "mini-batch epochs must run zero-collect (stats: {:?})",
        cluster.cache().stats()
    );
    // 2 batches per epoch, each repartitioning only w; warmup is X, the
    // anonymous X^2, and the one-time broadcast registration of the
    // loop-invariant mu and sigma vectors (cache hits afterwards, so
    // they are not re-broadcast per batch). Slices never blockify —
    // they select resident blocks (first epoch populates the derived
    // entries, later epochs reuse them).
    assert!(
        cluster.blockify_count() <= 2 * EPOCHS + 4,
        "mini-batch blockify budget exceeded: {} > {}",
        cluster.blockify_count(),
        2 * EPOCHS + 4
    );
    let explain = interp.output().join("\n");
    assert!(
        explain.contains("aligned, shuffle-free"),
        "block-aligned batch slices must be selection-only:\n{explain}"
    );
    assert!(explain.contains("BCAST"), "normalization must broadcast-join:\n{explain}");
    // Numerics agree with the all-CP run at matmult tolerance.
    let (_, cp_out, _) = run_inspectable(&mk(x), &dist_config(512 * 1024 * 1024, 64));
    let wa = cp_out.get("w").unwrap().as_matrix().unwrap().to_row_major_vec();
    let wb = out.get("w").unwrap().as_matrix().unwrap().to_row_major_vec();
    assert!(approx_eq_slice(&wa, &wb, 1e-9));
    let (na, nb) = (
        cp_out.get("wnorm").unwrap().as_double().unwrap(),
        out.get("wnorm").unwrap().as_double().unwrap(),
    );
    assert!((na - nb).abs() <= 1e-9 * na.abs().max(1.0), "{na} vs {nb}");
}

/// The distributed mini-batch primitives agree with their CP kernels on
/// random shapes (direct backend-level property check, complementing the
/// script-level parity above).
#[test]
fn property_blocked_indexing_matches_cp() {
    let cluster = Cluster::new(3, 16);
    for seed in 0..12u64 {
        let r = 8 + (seed as usize * 7) % 57;
        let c = 8 + (seed as usize * 11) % 41;
        let m = rand(r, c, -2.0, 2.0, 0.5, Pdf::Uniform, 900 + seed).unwrap();
        let b = systemml::runtime::dist::BlockedMatrix::from_local(&m, 16).unwrap();
        let rl = (seed as usize * 3) % (r / 2);
        let ru = rl + 1 + (seed as usize * 5) % (r - rl - 1).max(1);
        let cl = (seed as usize * 2) % (c / 2);
        let cu = cl + 1 + (seed as usize * 13) % (c - cl - 1).max(1);
        let local = reorg::slice(&m, rl, ru, cl, cu).unwrap();
        let dist = ops::slice_blocked(&cluster, &b, rl, ru, cl, cu)
            .unwrap()
            .to_local()
            .unwrap();
        assert_eq!(
            dist.to_row_major_vec(),
            local.to_row_major_vec(),
            "seed {seed}: [{rl}:{ru},{cl}:{cu}] of {r}x{c}"
        );
        // Write the slice back somewhere else and compare again.
        let wr = (r - (ru - rl)) / 2;
        let wc = (c - (cu - cl)) / 2;
        let l_cp = reorg::left_index(&m, wr, wc, &local).unwrap();
        let l_dist = ops::left_index_blocked(&cluster, &b, wr, wc, &local, false)
            .unwrap()
            .to_local()
            .unwrap();
        assert_eq!(l_dist.to_row_major_vec(), l_cp.to_row_major_vec(), "seed {seed}: write");
    }
    // Matmult over a slice (the batch-gradient shape) stays exact to 1e-9.
    let m = rand(64, 32, -1.0, 1.0, 1.0, Pdf::Uniform, 990).unwrap();
    let b = systemml::runtime::dist::BlockedMatrix::from_local(&m, 16).unwrap();
    let batch = ops::slice_blocked(&cluster, &b, 16, 48, 0, 32).unwrap();
    let w = rand(32, 1, -1.0, 1.0, 1.0, Pdf::Uniform, 991).unwrap();
    let wb = systemml::runtime::dist::BlockedMatrix::from_local(&w, 16).unwrap();
    let prod = ops::matmult_blocked(&cluster, &batch, &wb).unwrap().to_local().unwrap();
    let expect = mult::matmult(&reorg::slice(&m, 16, 48, 0, 32).unwrap(), &w).unwrap();
    assert!(approx_eq_slice(&prod.to_row_major_vec(), &expect.to_row_major_vec(), 1e-9));
}
