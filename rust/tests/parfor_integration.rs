//! parfor integration: optimizer decisions, remote task accounting,
//! result merging under concurrency, and failure propagation.

use systemml::api::{MLContext, Script};
use systemml::conf::SystemConfig;
use systemml::runtime::matrix::Matrix;
use systemml::util::metrics;

fn ctx_with_workers(n: usize) -> MLContext {
    let mut c = SystemConfig::default();
    c.num_workers = n;
    MLContext::with_config(c)
}

#[test]
fn parfor_merges_disjoint_row_blocks() {
    let ctx = ctx_with_workers(4);
    let script = Script::from_str(
        r#"
        P = matrix(0, rows=32, cols=3)
        parfor (i in 1:8) {
          beg = (i-1)*4 + 1; end = i*4
          P[beg:end, ] = matrix(i, rows=4, cols=3)
        }
        s = sum(P)
        "#,
    )
    .output("P")
    .output("s");
    let res = ctx.execute(script).unwrap();
    assert_eq!(res.double("s").unwrap(), (1..=8).sum::<i32>() as f64 * 12.0);
    let p = res.matrix("P").unwrap();
    assert_eq!(p.get(0, 0), 1.0);
    assert_eq!(p.get(31, 2), 8.0);
}

#[test]
fn parfor_remote_mode_counts_cluster_tasks() {
    let ctx = ctx_with_workers(4);
    let before = metrics::global().snapshot();
    let script = Script::from_str(
        r#"
        P = matrix(0, rows=16, cols=1)
        parfor (i in 1:16, mode=remote) {
          P[i, ] = i * i
        }
        "#,
    )
    .output("P");
    let res = ctx.execute(script).unwrap();
    let d = metrics::global().snapshot().delta(&before);
    // Lower bound: the metric counters are process-global and other
    // tests in this binary may run parfor concurrently.
    assert!(d.parfor_tasks >= 16, "parfor tasks: {}", d.parfor_tasks);
    assert!(d.dist_tasks >= 16, "remote parfor iterations are cluster tasks");
    assert_eq!(d.shuffle_bytes, 0, "row-partitioned parfor must not shuffle");
    assert_eq!(res.matrix("P").unwrap().get(15, 0), 256.0);
}

#[test]
fn parfor_degree_capped_by_par_option() {
    let ctx = ctx_with_workers(8);
    // par=2 forces 2 chunks even with 8 workers; result must be identical.
    let script = Script::from_str(
        r#"
        P = matrix(0, rows=12, cols=1)
        parfor (i in 1:12, par=2, mode=local) {
          P[i, ] = 2 * i
        }
        t = sum(P)
        "#,
    )
    .output("t");
    let res = ctx.execute(script).unwrap();
    assert_eq!(res.double("t").unwrap(), 2.0 * (1..=12).sum::<i32>() as f64);
}

#[test]
fn parfor_error_in_worker_propagates() {
    let ctx = ctx_with_workers(4);
    let script = Script::from_str(
        r#"
        P = matrix(0, rows=8, cols=1)
        parfor (i in 1:8) {
          if (i == 5) { stop("iteration failed") }
          P[i, ] = i
        }
        "#,
    );
    let err = ctx.execute(script).unwrap_err();
    assert!(err.to_string().contains("iteration failed"), "{err}");
}

#[test]
fn parfor_inner_heavy_op_still_correct() {
    // Each iteration does a matmult on a shared read-only input.
    let ctx = ctx_with_workers(4);
    let x = Matrix::filled(16, 16, 0.5);
    let script = Script::from_str(
        r#"
        n = 8
        P = matrix(0, rows=n, cols=1)
        parfor (i in 1:n) {
          Y = X %*% X
          P[i, ] = sum(Y) + i
        }
        "#,
    )
    .input("X", x.clone())
    .output("P");
    let res = ctx.execute(script).unwrap();
    let expected_base = 16.0 * 16.0 * (16.0 * 0.25);
    for i in 0..8 {
        assert!((res.matrix("P").unwrap().get(i, 0) - (expected_base + (i + 1) as f64)).abs() < 1e-9);
    }
}

#[test]
fn nested_for_inside_parfor() {
    let ctx = ctx_with_workers(2);
    let script = Script::from_str(
        r#"
        P = matrix(0, rows=6, cols=1)
        parfor (i in 1:6) {
          acc = 0
          for (j in 1:i) { acc = acc + j }
          P[i, ] = acc
        }
        "#,
    )
    .output("P");
    let res = ctx.execute(script).unwrap();
    let p = res.matrix("P").unwrap();
    for i in 1..=6usize {
        assert_eq!(p.get(i - 1, 0), (i * (i + 1) / 2) as f64);
    }
}

#[test]
fn parfor_loop_variable_visible_after() {
    let ctx = ctx_with_workers(2);
    let script = Script::from_str(
        r#"
        P = matrix(0, rows=4, cols=1)
        parfor (i in 1:4) { P[i, ] = i }
        last = i
        "#,
    )
    .output("last");
    assert_eq!(ctx.execute(script).unwrap().double("last").unwrap(), 4.0);
}

#[test]
fn column_partitioned_parfor() {
    let ctx = ctx_with_workers(4);
    let script = Script::from_str(
        r#"
        P = matrix(0, rows=3, cols=8)
        parfor (j in 1:8) {
          P[, j] = matrix(j, rows=3, cols=1)
        }
        cs = colSums(P)
        "#,
    )
    .output("cs");
    let cs = ctx.execute(script).unwrap().matrix("cs").unwrap();
    for j in 0..8 {
        assert_eq!(cs.get(0, j), 3.0 * (j + 1) as f64);
    }
}
