//! Classic-ML algorithm scripts (`scripts/algorithms/`): the paper's
//! "unified framework for machine learning and deep learning" claim —
//! the same language/runtime runs LinearRegCG, multinomial logistic
//! regression, k-means and PCA next to the NN library.

use systemml::api::{MLContext, Script};
use systemml::runtime::matrix::agg;
use systemml::runtime::matrix::randgen::{rand, synthetic_classification, Pdf};
use systemml::runtime::matrix::{elementwise, mult, Matrix};

fn ctx() -> MLContext {
    MLContext::new()
}

#[test]
fn linear_regression_cg_recovers_weights() {
    // y = X w* + noise; CG must recover w* closely.
    let n = 200;
    let d = 12;
    let x = rand(n, d, -1.0, 1.0, 1.0, Pdf::Uniform, 1).unwrap();
    let w_true = rand(d, 1, -2.0, 2.0, 1.0, Pdf::Uniform, 2).unwrap();
    let noise = rand(n, 1, -0.01, 0.01, 1.0, Pdf::Uniform, 3).unwrap();
    let y = elementwise::binary(
        &mult::matmult(&x, &w_true).unwrap(),
        &noise,
        elementwise::BinOp::Add,
    )
    .unwrap();
    let script = Script::from_str(
        r#"
        source("algorithms/lm_cg.dml") as lm
        [w, final_norm, iters] = lm::train(X, y, 0.0001, 60, 0.0000001)
        yhat = lm::predict(X, w)
        mse = sum((yhat - y)^2) / nrow(y)
        "#,
    )
    .input("X", x)
    .input("y", y)
    .output("w")
    .output("mse")
    .output("iters");
    let res = ctx().execute(script).unwrap();
    assert!(res.double("mse").unwrap() < 1e-3, "mse {}", res.double("mse").unwrap());
    let w = res.matrix("w").unwrap();
    for i in 0..d {
        assert!(
            (w.get(i, 0) - w_true.get(i, 0)).abs() < 0.05,
            "w[{i}] {} vs {}",
            w.get(i, 0),
            w_true.get(i, 0)
        );
    }
    assert!(res.double("iters").unwrap() <= 60.0);
}

#[test]
fn logistic_regression_separates_classes() {
    let (x, y) = synthetic_classification(240, 10, 3, 5);
    let script = Script::from_str(
        r#"
        source("algorithms/logistic.dml") as mlr
        [W, losses] = mlr::train(X, Y, 0.5, 0.001, 60)
        P = mlr::predict(X, W)
        acc = mean(rowIndexMax(P) == rowIndexMax(Y))
        first_loss = as.scalar(losses[1, 1])
        last_loss = as.scalar(losses[60, 1])
        "#,
    )
    .input("X", x)
    .input("Y", y)
    .output("acc")
    .output("first_loss")
    .output("last_loss");
    let res = ctx().execute(script).unwrap();
    assert!(res.double("acc").unwrap() > 0.9, "acc {}", res.double("acc").unwrap());
    assert!(res.double("last_loss").unwrap() < res.double("first_loss").unwrap() * 0.5);
}

#[test]
fn kmeans_clusters_separated_blobs() {
    // Three well-separated gaussian blobs; k-means must give low WCSS and
    // consistent assignments within blobs.
    let (x, y) = synthetic_classification(150, 6, 3, 9);
    let script = Script::from_str(
        r#"
        source("algorithms/kmeans.dml") as km
        [C, assign, wcss] = km::train(X, 3, 15, 7)
        "#,
    )
    .input("X", x)
    .output("C")
    .output("assign")
    .output("wcss");
    let res = ctx().execute(script).unwrap();
    assert_eq!(res.matrix("C").unwrap().shape(), (3, 6));
    let assign = res.matrix("assign").unwrap();
    // Cluster purity vs the generating labels (labels unknown to kmeans):
    // for each true class, the dominant cluster should cover >80%.
    let truth = agg::row_index_max(&y);
    let mut purity_total = 0usize;
    for class in 1..=3 {
        let mut counts = [0usize; 4];
        let mut class_n = 0usize;
        for r in 0..150 {
            if truth.get(r, 0) == class as f64 {
                counts[assign.get(r, 0) as usize] += 1;
                class_n += 1;
            }
        }
        let dominant = *counts.iter().max().unwrap();
        assert!(
            dominant * 10 >= class_n * 8,
            "class {class}: dominant cluster covers {dominant}/{class_n}"
        );
        purity_total += dominant;
    }
    assert!(purity_total >= 120);
}

#[test]
fn pca_finds_dominant_direction() {
    // Data stretched along a known direction: first component must align.
    let n = 300;
    let base = rand(n, 1, -1.0, 1.0, 1.0, Pdf::Uniform, 11).unwrap();
    let noise = rand(n, 4, -0.05, 0.05, 1.0, Pdf::Uniform, 12).unwrap();
    // X = base * dir + noise, dir = (2, 1, 0, -1)/sqrt(6)
    let dir = Matrix::from_rows(&[&[2.0, 1.0, 0.0, -1.0]]);
    let x = elementwise::binary(
        &mult::matmult(&base, &dir).unwrap(),
        &noise,
        elementwise::BinOp::Add,
    )
    .unwrap();
    let script = Script::from_str(
        r#"
        source("algorithms/pca.dml") as pca
        [components, evalues] = pca::train(X, 2, 80)
        Z = pca::transform(X, components)
        "#,
    )
    .input("X", x)
    .output("components")
    .output("evalues")
    .output("Z");
    let res = ctx().execute(script).unwrap();
    let comp = res.matrix("components").unwrap();
    // cos similarity of first component with the true direction.
    let norm_dir = 6.0f64.sqrt();
    let mut dot = 0.0;
    for i in 0..4 {
        dot += comp.get(i, 0) * dir.get(0, i) / norm_dir;
    }
    assert!(dot.abs() > 0.99, "cosine {dot}");
    let ev = res.matrix("evalues").unwrap();
    assert!(ev.get(0, 0) > 10.0 * ev.get(1, 0), "dominant eigenvalue must dominate");
    assert_eq!(res.matrix("Z").unwrap().shape(), (n, 2));
}

#[test]
fn extended_layers_smoke_and_gradients() {
    // The U-Net/transformer-plumbing layers added beyond the core 24.
    let res = ctx()
        .execute(
            Script::from_str(
                r#"
        source("nn/layers/gelu.dml") as gelu
        source("nn/layers/swish.dml") as swish
        source("nn/layers/softplus.dml") as softplus
        source("nn/layers/huber_loss.dml") as huber
        source("nn/layers/layer_norm.dml") as ln
        source("nn/layers/global_avg_pool2d.dml") as gap
        source("nn/layers/padding2d.dml") as padl
        source("nn/layers/upsample2d.dml") as up

        X = rand(rows=4, cols=8, min=-2, max=2, seed=1)
        g = gelu::forward(X)
        s = swish::forward(X)
        sp = softplus::forward(X)
        y = rand(rows=4, cols=8, min=-2, max=2, seed=2)
        hl = huber::forward(X, y, 1.0)
        [gamma, beta] = ln::init(8)
        lno = ln::forward(X, gamma, beta, 0.00001)
        lnm = max(abs(rowMeans(lno)))

        I = rand(rows=2, cols=1*4*4, min=0, max=1, seed=3)
        gp = gap::forward(I, 1, 4, 4)
        [P, Hp, Wp] = padl::forward(I, 1, 4, 4, 1)
        [U, Hu, Wu] = up::forward(I, 1, 4, 4)
        up_mean_diff = abs(mean(U) - mean(I))
        pad_sum_diff = abs(sum(P) - sum(I))
        "#,
            )
            .output("g")
            .output("s")
            .output("sp")
            .output("hl")
            .output("lnm")
            .output("gp")
            .output("up_mean_diff")
            .output("pad_sum_diff"),
        )
        .unwrap();
    // gelu(0)=0 region sanity: outputs bounded by |x|.
    assert_eq!(res.matrix("g").unwrap().shape(), (4, 8));
    assert!(res.double("hl").unwrap() > 0.0);
    assert!(res.double("lnm").unwrap() < 1e-9, "layer-norm rows must be zero-mean");
    assert_eq!(res.matrix("gp").unwrap().shape(), (2, 1));
    assert!(res.double("up_mean_diff").unwrap() < 1e-12, "NN upsample preserves the mean");
    assert!(res.double("pad_sum_diff").unwrap() < 1e-12, "zero-padding preserves the sum");

    // Numeric gradient checks for swish/softplus/huber.
    for (name, setup, loss, grad) in [
        (
            "swish",
            "source(\"nn/layers/swish.dml\") as l\ndout = matrix(1, rows=3, cols=4)",
            "sum(l::forward(X))",
            "l::backward(dout, X)",
        ),
        (
            "softplus",
            "source(\"nn/layers/softplus.dml\") as l\ndout = matrix(1, rows=3, cols=4)",
            "sum(l::forward(X))",
            "l::backward(dout, X)",
        ),
        (
            "huber",
            "source(\"nn/layers/huber_loss.dml\") as l\ny = matrix(0.2, rows=3, cols=4)",
            "l::forward(X, y, 1.0)",
            "l::backward(X, y, 1.0)",
        ),
    ] {
        let x = rand(3, 4, -2.0, 2.0, 1.0, Pdf::Uniform, 55).unwrap();
        let src = format!("{setup}\nloss_v = {loss}\ngrad_v = {grad}");
        let script = Script::from_str(&src).input("X", x.clone()).output("grad_v");
        let analytic = ctx().execute(script).unwrap().matrix("grad_v").unwrap();
        let eps = 1e-5;
        for idx in [0usize, 5, 11] {
            let (r, c) = (idx / 4, idx % 4);
            let mut xp = x.to_dense();
            xp.set(r, c, xp.get(r, c) + eps);
            let lp = eval_scalar(&format!("{setup}\nloss_v = {loss}"), &Matrix::Dense(xp.clone()));
            xp.set(r, c, xp.get(r, c) - 2.0 * eps);
            let lm = eval_scalar(&format!("{setup}\nloss_v = {loss}"), &Matrix::Dense(xp));
            let numeric = (lp - lm) / (2.0 * eps);
            assert!(
                (numeric - analytic.get(r, c)).abs() < 1e-4,
                "{name} grad at ({r},{c}): {numeric} vs {}",
                analytic.get(r, c)
            );
        }
    }
}

fn eval_scalar(src: &str, x: &Matrix) -> f64 {
    ctx()
        .execute(Script::from_str(src).input("X", x.clone()).output("loss_v"))
        .unwrap()
        .double("loss_v")
        .unwrap()
}
