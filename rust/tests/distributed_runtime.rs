//! Distributed-backend integration: blocked matrices over the simulated
//! cluster agree with local execution, and the communication accounting
//! matches the plan shapes (broadcast vs shuffle).

use systemml::runtime::dist::{ops, BlockedMatrix, Cluster};
use systemml::runtime::matrix::agg::AggOp;
use systemml::runtime::matrix::elementwise::BinOp;
use systemml::runtime::matrix::randgen::{rand, Pdf};
use systemml::runtime::matrix::{agg, elementwise, mult};
use systemml::util::metrics;
use systemml::util::quickcheck::{approx_eq_slice, forall_sized};
use systemml::util::prng::Prng;

#[test]
fn property_blockify_roundtrip() {
    forall_sized(
        "blockify-roundtrip",
        24,
        200,
        |rng: &mut Prng, size| {
            let r = 1 + rng.next_usize(size.max(1));
            let c = 1 + rng.next_usize(size.max(1));
            let density = [1.0, 0.3, 0.02][rng.next_usize(3)];
            rand(r, c, -1.0, 1.0, density, Pdf::Uniform, rng.next_u64()).unwrap()
        },
        |m| {
            let b = BlockedMatrix::from_local(m, 32).unwrap();
            b.to_local().unwrap() == *m && b.nnz() == m.nnz()
        },
    );
}

#[test]
fn property_dist_matmult_equals_local() {
    let cluster = Cluster::new(4, 24);
    forall_sized(
        "dist-matmult",
        12,
        80,
        |rng: &mut Prng, size| {
            let m = 1 + rng.next_usize(size.max(1));
            let k = 1 + rng.next_usize(size.max(1));
            let n = 1 + rng.next_usize(size.max(1));
            let density = [1.0, 0.2][rng.next_usize(2)];
            (
                rand(m, k, -1.0, 1.0, density, Pdf::Uniform, rng.next_u64()).unwrap(),
                rand(k, n, -1.0, 1.0, density, Pdf::Uniform, rng.next_u64()).unwrap(),
            )
        },
        |(a, b)| {
            let local = mult::matmult(a, b).unwrap();
            let dist = ops::matmult(&cluster, a, b).unwrap();
            approx_eq_slice(&dist.to_row_major_vec(), &local.to_row_major_vec(), 1e-9)
        },
    );
}

#[test]
fn property_dist_cellops_equal_local() {
    let cluster = Cluster::new(3, 16);
    forall_sized(
        "dist-cellops",
        16,
        60,
        |rng: &mut Prng, size| {
            let r = 1 + rng.next_usize(size.max(1));
            let c = 1 + rng.next_usize(size.max(1));
            (
                rand(r, c, -2.0, 2.0, 0.5, Pdf::Uniform, rng.next_u64()).unwrap(),
                rand(r, c, -2.0, 2.0, 0.5, Pdf::Uniform, rng.next_u64()).unwrap(),
            )
        },
        |(a, b)| {
            let ab = BlockedMatrix::from_local(a, 16).unwrap();
            let bb = BlockedMatrix::from_local(b, 16).unwrap();
            [BinOp::Add, BinOp::Mul, BinOp::Min].iter().all(|op| {
                let local = elementwise::binary(a, b, *op).unwrap();
                let dist =
                    ops::binary_blocked(&cluster, &ab, &bb, *op).unwrap().to_local().unwrap();
                approx_eq_slice(&dist.to_row_major_vec(), &local.to_row_major_vec(), 1e-12)
            })
        },
    );
}

#[test]
fn property_dist_aggregates_equal_local() {
    let cluster = Cluster::new(4, 20);
    forall_sized(
        "dist-agg",
        16,
        70,
        |rng: &mut Prng, size| {
            let r = 1 + rng.next_usize(size.max(1));
            let c = 1 + rng.next_usize(size.max(1));
            rand(r, c, -2.0, 2.0, 0.4, Pdf::Uniform, rng.next_u64()).unwrap()
        },
        |m| {
            let b = BlockedMatrix::from_local(m, 20).unwrap();
            [AggOp::Sum, AggOp::Min, AggOp::Max, AggOp::Mean].iter().all(|op| {
                (agg::full_agg(m, *op) - ops::full_agg_blocked(&cluster, &b, *op)).abs() < 1e-9
            })
        },
    );
}

#[test]
fn rmm_shuffles_mapmm_broadcasts() {
    let cluster = Cluster::new(4, 64);
    // Small rhs → mapmm (broadcast only).
    let a = rand(256, 128, -1.0, 1.0, 1.0, Pdf::Uniform, 9).unwrap();
    let b = rand(128, 32, -1.0, 1.0, 1.0, Pdf::Uniform, 10).unwrap();
    let m0 = metrics::global().snapshot();
    ops::matmult(&cluster, &a, &b).unwrap();
    let d1 = metrics::global().snapshot().delta(&m0);
    assert!(d1.broadcast_bytes > 0);
    assert_eq!(d1.shuffle_bytes, 0);
}

#[test]
fn worker_balance_on_uniform_blocks() {
    let cluster = Cluster::new(4, 32);
    cluster.reset_accounting();
    let a = rand(512, 128, -1.0, 1.0, 1.0, Pdf::Uniform, 11).unwrap();
    let b = rand(128, 128, -1.0, 1.0, 1.0, Pdf::Uniform, 12).unwrap();
    ops::matmult(&cluster, &a, &b).unwrap();
    let wf = cluster.worker_flops();
    let max = *wf.iter().max().unwrap() as f64;
    let min = *wf.iter().min().unwrap() as f64;
    assert!(min > 0.0, "all workers busy: {wf:?}");
    assert!(max / min < 4.0, "imbalance too high: {wf:?}");
}

#[test]
fn modeled_scaling_is_linearish_for_balanced_work() {
    // The E3-style modeled-time claim: doubling workers ~halves modeled
    // time for shuffle-free, balanced workloads.
    let a = rand(512, 256, -1.0, 1.0, 1.0, Pdf::Uniform, 13).unwrap();
    let b = rand(256, 64, -1.0, 1.0, 1.0, Pdf::Uniform, 14).unwrap();
    let mut times = Vec::new();
    for workers in [1usize, 2, 4, 8] {
        let cluster = Cluster::new(workers, 64);
        cluster.reset_accounting();
        ops::matmult(&cluster, &a, &b).unwrap();
        times.push(cluster.modeled_time_seconds(1e9, 0));
    }
    for w in 1..times.len() {
        let speedup = times[0] / times[w];
        let ideal = (1 << w) as f64;
        assert!(
            speedup > ideal * 0.5,
            "modeled speedup at {}x workers: {speedup:.2} (ideal {ideal})",
            1 << w
        );
    }
}

// ---- block-partition cache (lineage-keyed reuse across statements) ----

use std::sync::Arc;

use systemml::api::{MLContext, Script};
use systemml::conf::SystemConfig;
use systemml::runtime::interp::{Interpreter, Scope};

/// Compile a script (placements + cache marking) and run it on a fresh
/// interpreter whose cluster we can inspect afterwards.
fn run_inspectable(
    script: &Script,
    config: &SystemConfig,
) -> (Interpreter, Scope, systemml::hop::plan::Plan) {
    let ctx = MLContext::with_config(config.clone());
    let comp = ctx.compile(script).expect("compile");
    let plan = comp.plan.clone();
    let mut interp = Interpreter::new(comp.bundle, config.clone());
    interp.plan = Some(Arc::new(comp.plan));
    let inputs: Scope = script.inputs.clone().into_iter().collect();
    let out = interp.run(inputs).expect("run");
    (interp, out, plan)
}

fn dist_config(budget: usize, block: usize) -> SystemConfig {
    let mut c = SystemConfig::tiny_driver(budget);
    c.block_size = block;
    c.num_workers = 4;
    c
}

fn square_input(n: usize, seed: u64) -> systemml::runtime::matrix::Matrix {
    rand(n, n, -1.0, 1.0, 1.0, Pdf::Uniform, seed).unwrap()
}

#[test]
fn cache_hit_after_repeated_use() {
    // X is blockified once; the second statement's operands and the
    // aggregate arguments all reuse resident partitions.
    let config = dist_config(32 * 1024, 32);
    let x = square_input(96, 40);
    let script = Script::from_str("s1 = sum(X %*% X)\ns2 = sum(X %*% X)")
        .input("X", x.clone())
        .output("s1")
        .output("s2");
    let (interp, out, _) = run_inspectable(&script, &config);
    let cluster = interp.cluster.as_ref().unwrap();
    assert_eq!(cluster.blockify_count(), 1, "X must blockify exactly once");
    let stats = cluster.cache().stats();
    assert!(stats.hits >= 3, "rhs reuse + pending reuse + statement reuse: {stats:?}");
    let expected = agg::full_agg(&mult::matmult(&x, &x).unwrap(), AggOp::Sum);
    let s1 = out.get("s1").unwrap().as_double().unwrap();
    let s2 = out.get("s2").unwrap().as_double().unwrap();
    assert!((s1 - expected).abs() < 1e-6 * expected.abs().max(1.0), "{s1} vs {expected}");
    assert_eq!(s1, s2);
}

#[test]
fn cache_invalidated_after_assignment() {
    // Rebinding X drops its resident partitions; the next DIST op must
    // see the new content (and the stats record the invalidation).
    let config = dist_config(32 * 1024, 32);
    let x = square_input(96, 41);
    let script = Script::from_str("s1 = sum(X %*% X)\nX = X + 1\ns2 = sum(X %*% X)")
        .input("X", x.clone())
        .output("s1")
        .output("s2");
    let (interp, out, _) = run_inspectable(&script, &config);
    let cluster = interp.cluster.as_ref().unwrap();
    let stats = cluster.cache().stats();
    assert!(stats.invalidations >= 1, "rebinding X must invalidate: {stats:?}");
    assert_eq!(cluster.blockify_count(), 2, "old and new X each blockify once");
    let x1 = elementwise::scalar_op(&x, 1.0, BinOp::Add, false).unwrap();
    let e1 = agg::full_agg(&mult::matmult(&x, &x).unwrap(), AggOp::Sum);
    let e2 = agg::full_agg(&mult::matmult(&x1, &x1).unwrap(), AggOp::Sum);
    let s1 = out.get("s1").unwrap().as_double().unwrap();
    let s2 = out.get("s2").unwrap().as_double().unwrap();
    assert!((s1 - e1).abs() < 1e-6 * e1.abs().max(1.0));
    assert!((s2 - e2).abs() < 1e-6 * e2.abs().max(1.0));
}

#[test]
fn cache_evicts_under_tiny_storage_budget() {
    // A storage budget that fits one 96x96 blocked matrix but not two
    // forces LRU eviction between the A- and B-statements; results stay
    // correct because eviction only costs a re-blockify.
    let mut config = dist_config(32 * 1024, 32);
    let one_matrix = 96 * 96 * 8;
    config.worker_storage = (one_matrix + one_matrix / 2) / config.num_workers;
    let a = square_input(96, 42);
    let b = square_input(96, 43);
    let script = Script::from_str("sa = sum(A %*% A)\nsb = sum(B %*% B)\nsa2 = sum(A %*% A)")
        .input("A", a.clone())
        .input("B", b)
        .output("sa")
        .output("sa2");
    let (interp, out, _) = run_inspectable(&script, &config);
    let cluster = interp.cluster.as_ref().unwrap();
    let stats = cluster.cache().stats();
    assert!(stats.evictions >= 1, "budget must force eviction: {stats:?}");
    assert!(
        stats.resident_bytes <= cluster.cache().budget(),
        "resident bytes within budget: {stats:?}"
    );
    let sa = out.get("sa").unwrap().as_double().unwrap();
    let sa2 = out.get("sa2").unwrap().as_double().unwrap();
    assert_eq!(sa, sa2, "eviction must not change results");
}

#[test]
fn cached_and_uncached_results_agree() {
    // Parity: the cache is purely a data-movement optimization — an
    // iterative loop computes identical numbers with it on or off.
    let src = "out = 0\n\
               for (i in 1:4) {\n\
                 s = sum(X %*% X)\n\
                 out = out + s / i\n\
                 X = X + 0.5\n\
               }";
    let x = square_input(96, 44);
    let mut on = dist_config(32 * 1024, 32);
    on.cache_enabled = true;
    let mut off = dist_config(32 * 1024, 32);
    off.cache_enabled = false;
    let mk = || {
        Script::from_str(src).input("X", x.clone()).output("out")
    };
    let r_on = MLContext::with_config(on).execute(mk()).unwrap();
    let r_off = MLContext::with_config(off).execute(mk()).unwrap();
    let v_on = r_on.double("out").unwrap();
    let v_off = r_off.double("out").unwrap();
    assert!(
        (v_on - v_off).abs() <= 1e-12 * v_off.abs().max(1.0),
        "cache on/off parity: {v_on} vs {v_off}"
    );
}

#[test]
fn blockify_empty_matrix_returns_empty_handle() {
    // Regression: a 0-row matrix legally flows out of indexing — it must
    // blockify to an empty handle, not error.
    let empty = systemml::runtime::matrix::Matrix::zeros(0, 5);
    let h = BlockedMatrix::from_local(&empty, 8).expect("empty blockify");
    assert_eq!(h.shape(), (0, 5));
    assert_eq!(h.block_rows(), 0);
    assert_eq!(h.nnz(), 0);
    let back = h.to_local().expect("empty collect");
    assert_eq!(back.shape(), (0, 5));

    // Degenerate inner dimension: (3x0) %*% (0x2) is an all-zero 3x2.
    let cluster = Cluster::new(2, 8);
    let a = systemml::runtime::matrix::Matrix::zeros(3, 0);
    let b = systemml::runtime::matrix::Matrix::zeros(0, 2);
    let out = ops::matmult(&cluster, &a, &b).expect("empty-k matmult");
    assert_eq!(out.shape(), (3, 2));
    assert_eq!(out.nnz(), 0);
}

/// Acceptance (tentpole): an lm_cg-style loop whose updates stay DIST
/// performs **zero** driver collects per iteration — in fact zero for
/// the whole run — because every multi-block DIST output is bound as a
/// first-class blocked value and every consumer accepts it in blocked
/// form (aggregates reduce per-block partials; the 1x1 of `t(p) %*% q`
/// returns with the job). The loop-invariant operand X blockifies once;
/// after the first iteration nothing is repartitioned at all (w, p and r
/// live blocked), so total blockifies are X, y, and w — independent of
/// the iteration count.
#[test]
fn iterative_loop_blockifies_invariant_operand_once() {
    const ITERS: u64 = 8;
    let src = "w = matrix(0, rows=ncol(X), cols=1)\n\
               r = t(X) %*% y\n\
               p = r\n\
               norm_r2 = sum(r^2)\n\
               i = 0\n\
               while (i < max_iter) {\n\
                 i = i + 1\n\
                 q = t(X) %*% (X %*% p) + 0.001 * p\n\
                 alpha = norm_r2 / as.scalar(t(p) %*% q)\n\
                 w = w + alpha * p\n\
                 r = r - alpha * q\n\
                 old_norm = norm_r2\n\
                 norm_r2 = sum(r^2)\n\
                 p = r + (norm_r2 / old_norm) * p\n\
               }";
    let mut config = dist_config(64 * 1024, 48);
    config.explain = true;
    let x = rand(160, 120, -1.0, 1.0, 1.0, Pdf::Uniform, 45).unwrap();
    let y = rand(160, 1, -1.0, 1.0, 1.0, Pdf::Uniform, 46).unwrap();
    let script = Script::from_str(src)
        .input("X", x)
        .input("y", y)
        .input_scalar("max_iter", ITERS as f64)
        .output("w");
    let (interp, out, plan) = run_inspectable(&script, &config);
    assert!(plan.is_cached("X"), "planner must mark X Cached: {:?}", plan.cached_vars);
    assert!(plan.render().contains("CACHE"), "{}", plan.render());

    let cluster = interp.cluster.as_ref().unwrap();
    // Zero driver collects per iteration (the tentpole claim): the loop
    // never materializes a blocked value. (Reading `w` below is the
    // first and only force.)
    assert_eq!(
        cluster.collect_count(),
        0,
        "updates must stay DIST end-to-end (stats: {:?})",
        cluster.cache().stats()
    );
    // Exact blockify budget: X and y partition during warmup, w when its
    // first update joins the blocked chain. Iterations repartition
    // nothing — independent of ITERS.
    assert_eq!(
        cluster.blockify_count(),
        3,
        "loop-invariant operands must blockify once (stats: {:?})",
        cluster.cache().stats()
    );
    let stats = cluster.cache().stats();
    assert!(stats.hits >= 2 * ITERS, "X/t(X) reuse every iteration: {stats:?}");
    let explain = interp.output().join("\n");
    assert!(explain.contains("CACHE(hit)"), "EXPLAIN must show cache hits:\n{explain}");
    assert!(explain.contains("CACHE(miss)"), "first use is an observable miss:\n{explain}");
    assert!(explain.contains("BLOCKED(reuse)"), "blocked operands must surface:\n{explain}");
    // Forcing the requested output is the one driver materialization.
    let w = out.get("w").unwrap().as_matrix().unwrap().clone();
    assert_eq!(w.shape(), (120, 1));
    assert_eq!(cluster.collect_count(), 1, "reading w forces exactly one collect");
}

// ---- first-class blocked values (kept distributed end-to-end) ---------

/// CP-vs-blocked parity, byte-identical: the same script — through a
/// user function, a loop and a parfor body — produces bit-identical
/// results with a huge driver (all CP) and a tiny driver (transpose and
/// cellwise ops distributed, values blocked end-to-end). Cellwise and
/// reorg operators preserve per-cell operation order exactly; matmult
/// parity is tolerance-based (separate test) because block-partial
/// accumulation legitimately reassociates floating-point addition.
#[test]
fn blocked_parity_byte_identical_through_function_and_parfor() {
    let src = "shift = function(matrix[double] A, double c) return (matrix[double] B) {\n\
                 B = abs(A) + c * t(A)\n\
               }\n\
               Y = shift(X, 0.5)\n\
               for (i in 1:2) {\n\
                 Y = sqrt(abs(Y)) + Y * 0.25\n\
               }\n\
               R = matrix(0, rows=nrow(X), cols=ncol(X))\n\
               parfor (j in 1:6) {\n\
                 R[, j] = Y[, j] * 2 + 1\n\
               }";
    let x = square_input(96, 50);
    let run = |budget: usize| {
        let mut config = dist_config(budget, 32);
        config.num_workers = 3;
        let script = Script::from_str(src)
            .input("X", x.clone())
            .output("Y")
            .output("R");
        run_inspectable(&script, &config)
    };
    let (cp_interp, cp_out, _) = run(512 * 1024 * 1024);
    let (dist_interp, dist_out, _) = run(16 * 1024);
    // (Remote parfor attributes tasks to the cluster even in CP plans, so
    // CP-ness is asserted via blockify instead.)
    assert_eq!(cp_interp.cluster.as_ref().unwrap().blockify_count(), 0, "huge budget stays CP");
    assert!(
        dist_interp.cluster.as_ref().unwrap().blockify_count() > 0,
        "tiny budget must distribute"
    );
    for name in ["Y", "R"] {
        let a = cp_out.get(name).unwrap().as_matrix().unwrap().to_row_major_vec();
        let b = dist_out.get(name).unwrap().as_matrix().unwrap().to_row_major_vec();
        assert_eq!(a, b, "{name} must be byte-identical across CP and blocked plans");
    }
}

/// CP-vs-blocked parity for matmult-heavy code (function + loop):
/// block-partial accumulation reassociates fp addition, so this compares
/// at 1e-9 relative — the documented summation-order caveat.
#[test]
fn blocked_parity_matmult_close_through_function() {
    let src = "gram = function(matrix[double] A) return (matrix[double] G) {\n\
                 G = t(A) %*% A\n\
               }\n\
               G = gram(X)\n\
               w = matrix(1, rows=ncol(X), cols=1)\n\
               for (i in 1:3) {\n\
                 v = G %*% w\n\
                 w = v / max(abs(v))\n\
               }\n\
               s = sum(G)";
    let x = rand(96, 80, -1.0, 1.0, 1.0, Pdf::Uniform, 51).unwrap();
    let run = |budget: usize| {
        let config = dist_config(budget, 32);
        let script = Script::from_str(src)
            .input("X", x.clone())
            .output("w")
            .output("s");
        run_inspectable(&script, &config)
    };
    let (_, cp_out, _) = run(512 * 1024 * 1024);
    let (dist_interp, dist_out, _) = run(16 * 1024);
    assert!(dist_interp.cluster.as_ref().unwrap().tasks() > 0);
    let wa = cp_out.get("w").unwrap().as_matrix().unwrap().to_row_major_vec();
    let wb = dist_out.get("w").unwrap().as_matrix().unwrap().to_row_major_vec();
    assert!(approx_eq_slice(&wa, &wb, 1e-9));
    let (sa, sb) = (
        cp_out.get("s").unwrap().as_double().unwrap(),
        dist_out.get("s").unwrap().as_double().unwrap(),
    );
    assert!((sa - sb).abs() <= 1e-9 * sa.abs().max(1.0), "{sa} vs {sb}");
}

/// Regression: spilling a *live* blocked value to the driver under
/// storage pressure preserves correctness — the spilled value
/// re-blockifies on its next DIST use and forces from its memoized
/// driver copy on CP use.
#[test]
fn eviction_spill_of_live_blocked_value_preserves_correctness() {
    let mut config = dist_config(32 * 1024, 32);
    // Budget fits roughly two 96x96 blocked matrices: keeping A2 and B2
    // alive simultaneously (plus cache entries) must force spills, not
    // errors.
    config.worker_storage = (96 * 96 * 8 * 2) / config.num_workers;
    let a = square_input(96, 52);
    let b = square_input(96, 53);
    let script = Script::from_str(
        "A2 = A %*% A\nB2 = B %*% B\nS = A2 + B2\ns = sum(S)",
    )
    .input("A", a.clone())
    .input("B", b.clone())
    .output("A2")
    .output("s");
    let (interp, out, _) = run_inspectable(&script, &config);
    let cluster = interp.cluster.as_ref().unwrap();
    assert!(
        cluster.spill_count() >= 1,
        "live blocked values over the storage budget must spill (spills {}, stats {:?})",
        cluster.spill_count(),
        cluster.cache().stats()
    );
    let a2 = mult::matmult(&a, &a).unwrap();
    let b2 = mult::matmult(&b, &b).unwrap();
    let expected =
        agg::full_agg(&elementwise::binary(&a2, &b2, BinOp::Add).unwrap(), AggOp::Sum);
    let s = out.get("s").unwrap().as_double().unwrap();
    assert!((s - expected).abs() <= 1e-9 * expected.abs().max(1.0), "{s} vs {expected}");
    assert!(approx_eq_slice(
        &out.get("A2").unwrap().as_matrix().unwrap().to_row_major_vec(),
        &a2.to_row_major_vec(),
        1e-9
    ));
}

/// Tentpole acceptance, function half: a DML user function invoked from
/// the main program executes under *compiled* placements (the planner
/// specializes the body per call site), not runtime-estimate fallback —
/// and the lm_cg loop through the function still performs zero collects.
#[test]
fn user_function_executes_under_compiled_placements_with_zero_collects() {
    const ITERS: u64 = 6;
    let src = "applyH = function(matrix[double] M, matrix[double] d, double lambda)\n\
                   return (matrix[double] q) {\n\
                 q = t(M) %*% (M %*% d) + lambda * d\n\
               }\n\
               w = matrix(0, rows=ncol(X), cols=1)\n\
               r = t(X) %*% y\n\
               p = r\n\
               norm_r2 = sum(r^2)\n\
               i = 0\n\
               while (i < max_iter) {\n\
                 i = i + 1\n\
                 q = applyH(X, p, 0.001)\n\
                 alpha = norm_r2 / as.scalar(t(p) %*% q)\n\
                 w = w + alpha * p\n\
                 r = r - alpha * q\n\
                 old_norm = norm_r2\n\
                 norm_r2 = sum(r^2)\n\
                 p = r + (norm_r2 / old_norm) * p\n\
               }";
    let mut config = dist_config(64 * 1024, 48);
    config.explain = true;
    let x = rand(160, 120, -1.0, 1.0, 1.0, Pdf::Uniform, 54).unwrap();
    let y = rand(160, 1, -1.0, 1.0, 1.0, Pdf::Uniform, 55).unwrap();
    let script = Script::from_str(src)
        .input("X", x)
        .input("y", y)
        .input_scalar("max_iter", ITERS as f64)
        .output("w");
    let (interp, _, plan) = run_inspectable(&script, &config);
    // The plan carries the function body, specialized at the call site,
    // with DIST placements on its heavy operators.
    let rendered = plan.render();
    assert!(rendered.contains("fn applyH"), "function body must be planned:\n{rendered}");
    assert!(
        plan.stmts.iter().any(|s| {
            s.target.starts_with("fn applyH")
                && s.ops
                    .iter()
                    .any(|o| o.exec == Some(systemml::hop::plan::ExecType::Dist))
        }),
        "function-body operators must carry compiled DIST placements:\n{rendered}"
    );
    let cluster = interp.cluster.as_ref().unwrap();
    assert_eq!(cluster.collect_count(), 0, "function-internal updates stay DIST");
    // The function's parameter M rebinds per call (fresh lineage), so the
    // feature matrix repartitions once per call — but never collects.
    assert_eq!(cluster.blockify_count(), ITERS + 3);
    // Runtime proof that the body ran under compiled placements: the
    // in-function transpose `t(M)` resolves " planned" once per call (the
    // warmup `t(X)` accounts for one more). Fallback dispatch would emit
    // these lines without the planned marker.
    let explain = interp.output().join("\n");
    let planned_transposes = explain
        .lines()
        .filter(|l| l.contains("r(t) (160x120) -> DIST") && l.contains(" planned"))
        .count() as u64;
    assert!(
        planned_transposes >= ITERS,
        "function-body t(M) must run under its compiled placement every call \
         ({planned_transposes} planned lines):\n{explain}"
    );
}

/// Distributed transpose is a real DIST reorg (block-index swap +
/// per-block transpose): planned by the compiler (OpKind::Reorg),
/// explained, shuffle-free under the symmetric placement, and it keeps
/// the result blocked for downstream consumers.
#[test]
fn dist_transpose_planned_explained_and_correct() {
    use systemml::hop::plan::{ExecType, OpKind};
    let mut config = dist_config(32 * 1024, 32);
    config.explain = true;
    let x = rand(90, 70, -1.0, 1.0, 0.5, Pdf::Uniform, 56).unwrap();
    let script = Script::from_str("Y = t(X)\ns = sum(Y * Y)")
        .input("X", x.clone())
        .output("Y")
        .output("s");
    let (interp, out, plan) = run_inspectable(&script, &config);
    assert_eq!(
        plan.placed_execs(OpKind::Reorg),
        vec![ExecType::Dist],
        "over-budget transpose must be planned DIST:\n{}",
        plan.render()
    );
    let explain = interp.output().join("\n");
    assert!(explain.contains("r(t)"), "transpose must be explained:\n{explain}");
    let cluster = interp.cluster.as_ref().unwrap();
    assert!(cluster.tasks() > 0);
    // Exact: per-block transpose moves cells without arithmetic.
    let expected = systemml::runtime::matrix::reorg::transpose(&x);
    assert_eq!(
        out.get("Y").unwrap().as_matrix().unwrap().to_row_major_vec(),
        expected.to_row_major_vec()
    );
    let s = out.get("s").unwrap().as_double().unwrap();
    let es = agg::full_agg(
        &elementwise::binary(&expected, &expected, BinOp::Mul).unwrap(),
        AggOp::Sum,
    );
    assert!((s - es).abs() <= 1e-9 * es.abs().max(1.0));
}

/// Scalar casts and shape arguments force blocked values through a clear
/// error path (no panics): as.scalar on a non-1x1 blocked value reports
/// its shape without collecting it.
#[test]
fn blocked_scalar_cast_errors_clearly() {
    let config = dist_config(32 * 1024, 32);
    let x = square_input(96, 57);
    let script = Script::from_str("Z = X %*% X\nv = as.scalar(Z)")
        .input("X", x)
        .output("v");
    let ctx = MLContext::with_config(config);
    let err = ctx.execute(script).unwrap_err().to_string();
    assert!(err.contains("as.scalar"), "{err}");
    assert!(err.contains("96x96"), "{err}");
}
