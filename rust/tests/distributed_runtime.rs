//! Distributed-backend integration: blocked matrices over the simulated
//! cluster agree with local execution, and the communication accounting
//! matches the plan shapes (broadcast vs shuffle).

use systemml::runtime::dist::{ops, BlockedMatrix, Cluster};
use systemml::runtime::matrix::agg::AggOp;
use systemml::runtime::matrix::elementwise::BinOp;
use systemml::runtime::matrix::randgen::{rand, Pdf};
use systemml::runtime::matrix::{agg, elementwise, mult};
use systemml::util::metrics;
use systemml::util::quickcheck::{approx_eq_slice, forall_sized};
use systemml::util::prng::Prng;

#[test]
fn property_blockify_roundtrip() {
    forall_sized(
        "blockify-roundtrip",
        24,
        200,
        |rng: &mut Prng, size| {
            let r = 1 + rng.next_usize(size.max(1));
            let c = 1 + rng.next_usize(size.max(1));
            let density = [1.0, 0.3, 0.02][rng.next_usize(3)];
            rand(r, c, -1.0, 1.0, density, Pdf::Uniform, rng.next_u64()).unwrap()
        },
        |m| {
            let b = BlockedMatrix::from_local(m, 32).unwrap();
            b.to_local().unwrap() == *m && b.nnz() == m.nnz()
        },
    );
}

#[test]
fn property_dist_matmult_equals_local() {
    let cluster = Cluster::new(4, 24);
    forall_sized(
        "dist-matmult",
        12,
        80,
        |rng: &mut Prng, size| {
            let m = 1 + rng.next_usize(size.max(1));
            let k = 1 + rng.next_usize(size.max(1));
            let n = 1 + rng.next_usize(size.max(1));
            let density = [1.0, 0.2][rng.next_usize(2)];
            (
                rand(m, k, -1.0, 1.0, density, Pdf::Uniform, rng.next_u64()).unwrap(),
                rand(k, n, -1.0, 1.0, density, Pdf::Uniform, rng.next_u64()).unwrap(),
            )
        },
        |(a, b)| {
            let local = mult::matmult(a, b).unwrap();
            let dist = ops::matmult(&cluster, a, b).unwrap();
            approx_eq_slice(&dist.to_row_major_vec(), &local.to_row_major_vec(), 1e-9)
        },
    );
}

#[test]
fn property_dist_cellops_equal_local() {
    let cluster = Cluster::new(3, 16);
    forall_sized(
        "dist-cellops",
        16,
        60,
        |rng: &mut Prng, size| {
            let r = 1 + rng.next_usize(size.max(1));
            let c = 1 + rng.next_usize(size.max(1));
            (
                rand(r, c, -2.0, 2.0, 0.5, Pdf::Uniform, rng.next_u64()).unwrap(),
                rand(r, c, -2.0, 2.0, 0.5, Pdf::Uniform, rng.next_u64()).unwrap(),
            )
        },
        |(a, b)| {
            let ab = BlockedMatrix::from_local(a, 16).unwrap();
            let bb = BlockedMatrix::from_local(b, 16).unwrap();
            [BinOp::Add, BinOp::Mul, BinOp::Min].iter().all(|op| {
                let local = elementwise::binary(a, b, *op).unwrap();
                let dist =
                    ops::binary_blocked(&cluster, &ab, &bb, *op).unwrap().to_local().unwrap();
                approx_eq_slice(&dist.to_row_major_vec(), &local.to_row_major_vec(), 1e-12)
            })
        },
    );
}

#[test]
fn property_dist_aggregates_equal_local() {
    let cluster = Cluster::new(4, 20);
    forall_sized(
        "dist-agg",
        16,
        70,
        |rng: &mut Prng, size| {
            let r = 1 + rng.next_usize(size.max(1));
            let c = 1 + rng.next_usize(size.max(1));
            rand(r, c, -2.0, 2.0, 0.4, Pdf::Uniform, rng.next_u64()).unwrap()
        },
        |m| {
            let b = BlockedMatrix::from_local(m, 20).unwrap();
            [AggOp::Sum, AggOp::Min, AggOp::Max, AggOp::Mean].iter().all(|op| {
                (agg::full_agg(m, *op) - ops::full_agg_blocked(&cluster, &b, *op)).abs() < 1e-9
            })
        },
    );
}

#[test]
fn rmm_shuffles_mapmm_broadcasts() {
    let cluster = Cluster::new(4, 64);
    // Small rhs → mapmm (broadcast only).
    let a = rand(256, 128, -1.0, 1.0, 1.0, Pdf::Uniform, 9).unwrap();
    let b = rand(128, 32, -1.0, 1.0, 1.0, Pdf::Uniform, 10).unwrap();
    let m0 = metrics::global().snapshot();
    ops::matmult(&cluster, &a, &b).unwrap();
    let d1 = metrics::global().snapshot().delta(&m0);
    assert!(d1.broadcast_bytes > 0);
    assert_eq!(d1.shuffle_bytes, 0);
}

#[test]
fn worker_balance_on_uniform_blocks() {
    let cluster = Cluster::new(4, 32);
    cluster.reset_accounting();
    let a = rand(512, 128, -1.0, 1.0, 1.0, Pdf::Uniform, 11).unwrap();
    let b = rand(128, 128, -1.0, 1.0, 1.0, Pdf::Uniform, 12).unwrap();
    ops::matmult(&cluster, &a, &b).unwrap();
    let wf = cluster.worker_flops();
    let max = *wf.iter().max().unwrap() as f64;
    let min = *wf.iter().min().unwrap() as f64;
    assert!(min > 0.0, "all workers busy: {wf:?}");
    assert!(max / min < 4.0, "imbalance too high: {wf:?}");
}

#[test]
fn modeled_scaling_is_linearish_for_balanced_work() {
    // The E3-style modeled-time claim: doubling workers ~halves modeled
    // time for shuffle-free, balanced workloads.
    let a = rand(512, 256, -1.0, 1.0, 1.0, Pdf::Uniform, 13).unwrap();
    let b = rand(256, 64, -1.0, 1.0, 1.0, Pdf::Uniform, 14).unwrap();
    let mut times = Vec::new();
    for workers in [1usize, 2, 4, 8] {
        let cluster = Cluster::new(workers, 64);
        cluster.reset_accounting();
        ops::matmult(&cluster, &a, &b).unwrap();
        times.push(cluster.modeled_time_seconds(1e9, 0));
    }
    for w in 1..times.len() {
        let speedup = times[0] / times[w];
        let ideal = (1 << w) as f64;
        assert!(
            speedup > ideal * 0.5,
            "modeled speedup at {}x workers: {speedup:.2} (ideal {ideal})",
            1 << w
        );
    }
}
