//! Keras2DML end-to-end: JSON model → generated DML → fit/predict via the
//! full runtime, mirroring the paper's §2 Python listing.

use systemml::nn::keras2dml::{Keras2DML, SequentialModel};
use systemml::runtime::matrix::agg;
use systemml::runtime::matrix::randgen::synthetic_classification;
use systemml::MLContext;

const SOFTMAX_JSON: &str = r#"{
    "name": "softmax_classifier",
    "input_dim": 16,
    "layers": [
        {"type": "dense", "units": 4, "activation": "softmax"}
    ],
    "optimizer": {"type": "sgd", "lr": 0.1}
}"#;

#[test]
fn paper_listing_fit_and_predict() {
    // model.add(Dense(K, activation="softmax", input_dim=D)); SGD(lr=0.1);
    // Keras2DML(...).set(train_algo="minibatch", test_algo="allreduce").fit(X, Y)
    let model = SequentialModel::from_json(SOFTMAX_JSON).unwrap();
    let mut k2d = Keras2DML::new(MLContext::new(), model);
    k2d.set("minibatch", "allreduce");
    let (x, y) = synthetic_classification(256, 16, 4, 77);
    let trained = k2d.fit(x.clone(), y.clone()).unwrap();

    // Loss must decrease over the epoch.
    let first = trained.loss_curve[0];
    let last = *trained.loss_curve.last().unwrap();
    assert!(last < first * 0.8, "loss did not drop: {first} -> {last}");

    // allreduce scoring: row-partitioned parfor, zero shuffle.
    let before = systemml::util::metrics::global().snapshot();
    let probs = k2d.predict(&trained, x.clone()).unwrap();
    let delta = systemml::util::metrics::global().snapshot().delta(&before);
    assert_eq!(probs.shape(), (256, 4));
    assert!(delta.parfor_tasks > 0, "allreduce scoring must run as parfor tasks");
    assert_eq!(delta.shuffle_bytes, 0, "row-partitioned scoring must not shuffle");

    // Probabilities: rows sum to 1.
    let rs = agg::row_agg(&probs, agg::AggOp::Sum);
    for r in 0..256 {
        assert!((rs.get(r, 0) - 1.0).abs() < 1e-9);
    }

    // Accuracy on separable synthetic data should beat chance soundly.
    let pred = agg::row_index_max(&probs);
    let truth = agg::row_index_max(&y);
    let correct = (0..256).filter(|r| pred.get(*r, 0) == truth.get(*r, 0)).count();
    assert!(correct > 128, "accuracy {}/256 not better than chance", correct);
}

#[test]
fn train_algo_batch_executes() {
    let model = SequentialModel::from_json(SOFTMAX_JSON).unwrap();
    let mut k2d = Keras2DML::new(MLContext::new(), model);
    k2d.set("batch", "naive");
    k2d.fit_config.epochs = 30;
    let (x, y) = synthetic_classification(64, 16, 4, 78);
    let trained = k2d.fit(x.clone(), y).unwrap();
    assert_eq!(trained.loss_curve.len(), 30); // one update per epoch
    let first = trained.loss_curve[0];
    let last = *trained.loss_curve.last().unwrap();
    assert!(last < first, "full-batch GD must descend: {first} -> {last}");
    let probs = k2d.predict(&trained, x).unwrap();
    assert_eq!(probs.shape(), (64, 4));
}

#[test]
fn momentum_and_adam_models_train() {
    for opt in [r#"{"type": "momentum", "lr": 0.05}"#, r#"{"type": "adam", "lr": 0.01}"#] {
        let json = format!(
            r#"{{
            "name": "m", "input_dim": 8,
            "layers": [
                {{"type": "dense", "units": 16, "activation": "tanh"}},
                {{"type": "dense", "units": 3, "activation": "softmax"}}
            ],
            "optimizer": {opt}
        }}"#
        );
        let model = SequentialModel::from_json(&json).unwrap();
        let k2d = Keras2DML::new(MLContext::new(), model);
        let (x, y) = synthetic_classification(128, 8, 3, 79);
        let trained = k2d.fit(x, y).unwrap();
        let first = trained.loss_curve[0];
        let last = *trained.loss_curve.last().unwrap();
        assert!(last < first, "{opt}: loss did not drop ({first} -> {last})");
    }
}

#[test]
fn cnn_model_trains_one_epoch() {
    let json = r#"{
        "name": "tiny_lenet",
        "input_shape": [1, 8, 8],
        "layers": [
            {"type": "conv2d", "filters": 4, "kernel": [3,3], "padding": "same", "activation": "relu"},
            {"type": "maxpool2d", "pool": [2,2]},
            {"type": "flatten"},
            {"type": "dense", "units": 3, "activation": "softmax"}
        ],
        "optimizer": {"type": "sgd", "lr": 0.1}
    }"#;
    let model = SequentialModel::from_json(json).unwrap();
    let mut k2d = Keras2DML::new(MLContext::new(), model);
    k2d.fit_config.batch_size = 16;
    k2d.fit_config.epochs = 2;
    let (x, y) =
        systemml::runtime::matrix::randgen::synthetic_images(64, 1, 8, 8, 3, 80);
    let trained = k2d.fit(x.clone(), y).unwrap();
    let first = trained.loss_curve[0];
    let last = *trained.loss_curve.last().unwrap();
    assert!(last < first, "CNN loss did not drop ({first} -> {last})");
    let probs = k2d.predict(&trained, x).unwrap();
    assert_eq!(probs.shape(), (64, 3));
}
