//! NN library tests: every layer's forward runs, and every backward is
//! verified against numeric differentiation *through the interpreter* —
//! the same gradient checks SystemML's nn library ships in nn/test.

use systemml::api::{MLContext, Script};
use systemml::runtime::matrix::randgen::{rand, Pdf};
use systemml::runtime::matrix::Matrix;

fn ctx() -> MLContext {
    MLContext::new()
}

/// Evaluate `loss_expr` (a scalar DML expression over matrix X plus fixed
/// setup) with X perturbed at (r, c) by eps.
fn eval_loss(setup: &str, loss_expr: &str, x: &Matrix) -> f64 {
    let src = format!("{setup}\nloss_value = {loss_expr}");
    let script = Script::from_str(src).input("X", x.clone()).output("loss_value");
    ctx().execute(script).unwrap().double("loss_value").unwrap()
}

/// Numeric-vs-analytic gradient check: `setup` sources libs, `loss_expr`
/// computes a scalar from X, `grad_expr` computes dX analytically.
fn grad_check(name: &str, setup: &str, loss_expr: &str, grad_expr: &str, x: &Matrix) {
    let src = format!("{setup}\nloss_value = {loss_expr}\ngrad_value = {grad_expr}");
    let script = Script::from_str(src).input("X", x.clone()).output("grad_value");
    let analytic = ctx().execute(script).unwrap().matrix("grad_value").unwrap();
    let eps = 1e-5;
    // Check a deterministic sample of cells.
    let cells: Vec<(usize, usize)> = (0..x.rows())
        .flat_map(|r| (0..x.cols()).map(move |c| (r, c)))
        .step_by(1 + x.len() / 6)
        .collect();
    for (r, c) in cells {
        let mut xp = x.to_dense();
        xp.set(r, c, xp.get(r, c) + eps);
        let lp = eval_loss(setup, loss_expr, &Matrix::Dense(xp.clone()));
        xp.set(r, c, xp.get(r, c) - 2.0 * eps);
        let lm = eval_loss(setup, loss_expr, &Matrix::Dense(xp));
        let numeric = (lp - lm) / (2.0 * eps);
        let ana = analytic.get(r, c);
        assert!(
            (numeric - ana).abs() < 1e-4 * (1.0 + numeric.abs().max(ana.abs())),
            "{name}: grad mismatch at ({r},{c}): numeric {numeric} vs analytic {ana}"
        );
    }
}

fn x_small(seed: u64) -> Matrix {
    rand(4, 6, -1.0, 1.0, 1.0, Pdf::Uniform, seed).unwrap()
}

#[test]
fn relu_gradient() {
    grad_check(
        "relu",
        r#"source("nn/layers/relu.dml") as relu
           dout = matrix(1, rows=4, cols=6)"#,
        "sum(relu::forward(X))",
        "relu::backward(dout, X)",
        &x_small(1),
    );
}

#[test]
fn leaky_relu_and_elu_gradients() {
    grad_check(
        "leaky_relu",
        r#"source("nn/layers/leaky_relu.dml") as lrelu
           dout = matrix(1, rows=4, cols=6)"#,
        "sum(lrelu::forward(X, 0.1))",
        "lrelu::backward(dout, X, 0.1)",
        &x_small(2),
    );
    grad_check(
        "elu",
        r#"source("nn/layers/elu.dml") as elu
           dout = matrix(1, rows=4, cols=6)"#,
        "sum(elu::forward(X, 1.0))",
        "elu::backward(dout, X, 1.0)",
        &x_small(3),
    );
}

#[test]
fn sigmoid_tanh_gradients() {
    grad_check(
        "sigmoid",
        r#"source("nn/layers/sigmoid.dml") as sig
           dout = matrix(1, rows=4, cols=6)"#,
        "sum(sig::forward(X))",
        "sig::backward(dout, X)",
        &x_small(4),
    );
    grad_check(
        "tanh",
        r#"source("nn/layers/tanh.dml") as th
           dout = matrix(1, rows=4, cols=6)"#,
        "sum(th::forward(X))",
        "th::backward(dout, X)",
        &x_small(5),
    );
}

#[test]
fn affine_gradient_wrt_input() {
    grad_check(
        "affine",
        r#"source("nn/layers/affine.dml") as affine
           W = rand(rows=6, cols=3, min=-1, max=1, seed=9)
           b = rand(rows=1, cols=3, min=-1, max=1, seed=10)
           dout = matrix(1, rows=4, cols=3)"#,
        "sum(affine::forward(X, W, b))",
        "as.matrix(affine::backward(dout, X, W, b))",
        &x_small(6),
    );
}

#[test]
fn softmax_cross_entropy_gradient() {
    // Gradient of CE(softmax(X), y) wrt X via the two layers' backwards.
    grad_check(
        "softmax+ce",
        r#"source("nn/layers/softmax.dml") as softmax
           source("nn/layers/cross_entropy_loss.dml") as ce
           y = table(seq(1, 4), matrix(seq(1, 4), rows=4, cols=1), 4, 6)"#,
        "ce::forward(softmax::forward(X), y)",
        "softmax::backward(ce::backward(softmax::forward(X), y), X)",
        &x_small(7),
    );
}

#[test]
fn l1_l2_losses_and_reg() {
    grad_check(
        "l2_loss",
        r#"source("nn/layers/l2_loss.dml") as l2
           y = matrix(0.5, rows=4, cols=6)"#,
        "l2::forward(X, y)",
        "l2::backward(X, y)",
        &x_small(8),
    );
    grad_check(
        "l2_reg",
        r#"source("nn/layers/l2_reg.dml") as reg"#,
        "reg::forward(X, 0.1)",
        "reg::backward(X, 0.1)",
        &x_small(9),
    );
    grad_check(
        "l1_reg",
        r#"source("nn/layers/l1_reg.dml") as reg"#,
        "reg::forward(X, 0.1)",
        "reg::backward(X, 0.1)",
        &x_small(10),
    );
}

#[test]
fn scale_shift_and_batch_norm_forward() {
    let ctx = ctx();
    let script = Script::from_str(
        r#"
        source("nn/layers/batch_norm1d.dml") as bn
        source("nn/layers/scale_shift.dml") as ss
        X = rand(rows=16, cols=4, min=-2, max=2, seed=20)
        [gamma, beta] = bn::init(4)
        [out, mu, varr] = bn::forward(X, gamma, beta, 1e-5)
        m_out = colMeans(out)
        v_out = colMeans(out^2) - m_out^2
        [g2, b2] = ss::init(4)
        out2 = ss::forward(out, g2 * 3, b2 + 1)
        check = mean(out2 - (out * 3 + 1))
        "#,
    )
    .output("m_out")
    .output("v_out")
    .output("check");
    let res = ctx.execute(script).unwrap();
    let m = res.matrix("m_out").unwrap();
    let v = res.matrix("v_out").unwrap();
    for c in 0..4 {
        assert!(m.get(0, c).abs() < 1e-10, "bn mean ~0");
        assert!((v.get(0, c) - 1.0).abs() < 1e-3, "bn var ~1, got {}", v.get(0, c));
    }
    assert!(res.double("check").unwrap().abs() < 1e-12);
}

#[test]
fn dropout_mask_properties() {
    let res = ctx()
        .execute(
            Script::from_str(
                r#"
        source("nn/layers/dropout.dml") as dropout
        X = matrix(1, rows=50, cols=40)
        [out, mask] = dropout::forward(X, 0.7, 99)
        keep_frac = sum(mask != 0) / length(mask)
        # inverted dropout preserves expectation
        mean_out = mean(out)
        dX = dropout::backward(matrix(1, rows=50, cols=40), mask)
        same = sum(dX != mask)
        "#,
            )
            .output("keep_frac")
            .output("mean_out")
            .output("same"),
        )
        .unwrap();
    let kf = res.double("keep_frac").unwrap();
    assert!((kf - 0.7).abs() < 0.05, "keep fraction {kf}");
    assert!((res.double("mean_out").unwrap() - 1.0).abs() < 0.1);
    assert_eq!(res.double("same").unwrap(), 0.0);
}

#[test]
fn conv_builtin_layer_matches_loop_layer() {
    // The paper's E7 claim: builtin and DML-loop conv compute the same
    // function (the builtin being much faster).
    let res = ctx()
        .execute(
            Script::from_str(
                r#"
        source("nn/layers/conv2d_builtin.dml") as conv_fast
        source("nn/layers/conv2d.dml") as conv_slow
        N = 2
        X = rand(rows=N, cols=2*5*5, min=-1, max=1, seed=30)
        [W, b] = conv_fast::init(3, 2, 3, 3)
        [fast, Hout, Wout] = conv_fast::forward(X, W, b, 2, 5, 5, 3, 3, 1, 1, 1, 1)
        [slow, Hout2, Wout2] = conv_slow::forward(X, W, b, 2, 5, 5, 3, 3, 1, 1)
        diff = max(abs(fast - slow))
        "#,
            )
            .output("diff"),
        )
        .unwrap();
    assert!(res.double("diff").unwrap() < 1e-9);
}

#[test]
fn pooling_layers_and_backward() {
    let res = ctx()
        .execute(
            Script::from_str(
                r#"
        source("nn/layers/max_pool2d_builtin.dml") as pool_fast
        source("nn/layers/max_pool2d.dml") as pool_slow
        source("nn/layers/avg_pool2d_builtin.dml") as apool
        N = 2
        X = rand(rows=N, cols=1*6*6, min=-1, max=1, seed=31)
        [fast, H1, W1] = pool_fast::forward(X, 1, 6, 6, 2, 2, 2, 2)
        [slow, H2, W2] = pool_slow::forward(X, 1, 6, 6, 2, 2, 2, 2)
        diff = max(abs(fast - slow))
        [avg, H3, W3] = apool::forward(X, 1, 6, 6, 2, 2, 2, 2)
        avg_check = abs(mean(avg) - mean(X))
        dX = pool_fast::backward(matrix(1, rows=N, cols=9), X, 1, 6, 6, 2, 2, 2, 2)
        routed = sum(dX != 0)
        "#,
            )
            .output("diff")
            .output("avg_check")
            .output("routed"),
        )
        .unwrap();
    assert!(res.double("diff").unwrap() < 1e-12);
    assert!(res.double("avg_check").unwrap() < 1e-12);
    assert_eq!(res.double("routed").unwrap(), 18.0); // one cell per window
}

#[test]
fn rnn_and_lstm_shapes_and_determinism() {
    let res = ctx()
        .execute(
            Script::from_str(
                r#"
        source("nn/layers/rnn.dml") as rnn
        source("nn/layers/lstm.dml") as lstm
        N = 3; T = 4; D = 5; M = 6
        X = rand(rows=N, cols=T*D, min=-1, max=1, seed=32)
        [W, U, b] = rnn::init(D, M)
        [out, h] = rnn::forward(X, W, U, b, T, D)
        [W2, b2] = lstm::init(D, M)
        [out2, c2] = lstm::forward(X, W2, b2, T, D)
        bound = max(max(abs(out)), max(abs(out2)))
        "#,
            )
            .output("out")
            .output("out2")
            .output("h")
            .output("c2")
            .output("bound"),
        )
        .unwrap();
    assert_eq!(res.matrix("out").unwrap().shape(), (3, 24));
    assert_eq!(res.matrix("out2").unwrap().shape(), (3, 24));
    assert_eq!(res.matrix("h").unwrap().shape(), (3, 6));
    assert!(res.double("bound").unwrap() <= 1.0 + 1e-9, "tanh-bounded activations");
}

#[test]
fn fm_low_rank_and_embedding() {
    let res = ctx()
        .execute(
            Script::from_str(
                r#"
        source("nn/layers/fm.dml") as fm
        source("nn/layers/low_rank_affine.dml") as lra
        source("nn/layers/embedding.dml") as emb
        X = rand(rows=4, cols=6, min=-1, max=1, seed=33)
        [w0, w, V] = fm::init(6, 2)
        yfm = fm::forward(X, w0, w, V)
        [U, Vl, b] = lra::init(6, 5, 2)
        ylra = lra::forward(X, U, Vl, b)
        E = emb::init(10, 3)
        ids = matrix(seq(1, 4), rows=4, cols=1)
        yemb = emb::forward(ids, E)
        ok = nrow(yfm) + ncol(ylra) + ncol(yemb)
        "#,
            )
            .output("yfm")
            .output("ylra")
            .output("yemb"),
        )
        .unwrap();
    assert_eq!(res.matrix("yfm").unwrap().shape(), (4, 1));
    assert_eq!(res.matrix("ylra").unwrap().shape(), (4, 5));
    assert_eq!(res.matrix("yemb").unwrap().shape(), (4, 3));
}

#[test]
fn all_six_optimizers_reduce_quadratic() {
    // Minimize f(X) = 0.5*||X||^2 with each optimizer; all must shrink X.
    let harness = |update_src: &str| -> f64 {
        let src = format!(
            r#"
            {update_src}
            final_norm = sum(X^2)
            "#
        );
        let script = Script::from_str(src)
            .input("X", Matrix::filled(4, 4, 1.0))
            .output("final_norm");
        ctx().execute(script).unwrap().double("final_norm").unwrap()
    };
    let sgd = harness(
        r#"source("nn/optim/sgd.dml") as sgd
           for (i in 1:20) { X = sgd::update(X, X, 0.1) }"#,
    );
    let mom = harness(
        r#"source("nn/optim/sgd_momentum.dml") as opt
           v = opt::init(X)
           for (i in 1:20) { [X, v] = opt::update(X, X, 0.1, 0.9, v) }"#,
    );
    let nest = harness(
        r#"source("nn/optim/sgd_nesterov.dml") as opt
           v = opt::init(X)
           for (i in 1:20) { [X, v] = opt::update(X, X, 0.1, 0.9, v) }"#,
    );
    let ada = harness(
        r#"source("nn/optim/adagrad.dml") as opt
           c = opt::init(X)
           for (i in 1:20) { [X, c] = opt::update(X, X, 0.5, 1e-8, c) }"#,
    );
    let rms = harness(
        r#"source("nn/optim/rmsprop.dml") as opt
           c = opt::init(X)
           for (i in 1:20) { [X, c] = opt::update(X, X, 0.05, 0.99, 1e-8, c) }"#,
    );
    let adam = harness(
        r#"source("nn/optim/adam.dml") as opt
           [m, v] = opt::init(X)
           for (i in 1:20) { [X, m, v] = opt::update(X, X, 0.1, 0.9, 0.999, 1e-8, i, m, v) }"#,
    );
    for (name, val) in
        [("sgd", sgd), ("momentum", mom), ("nesterov", nest), ("adagrad", ada), ("rmsprop", rms), ("adam", adam)]
    {
        assert!(val < 16.0 * 0.5, "{name} failed to reduce ||X||²: {val}");
    }
}
