//! Resident-training gates (PR 7): a multi-epoch SGD-momentum job keeps
//! its weights and optimizer state cluster-resident for the whole run —
//! gradients combine via a modeled tree-allreduce and the update chain
//! stays replicated on the workers, so the driver never collects. The
//! deterministic fold order (ascending block index, driver-side) makes
//! the trained weights **byte-identical** across every cluster shape:
//! worker counts 1/2/4/7 and thread counts 1/4. Spilling the resident
//! state under storage pressure must not change a single bit either.

use systemml::api::{MLContext, Script};
use systemml::conf::SystemConfig;
use systemml::runtime::interp::Value;
use systemml::runtime::matrix::randgen::{rand, Pdf};
use systemml::runtime::matrix::Matrix;

/// Three epochs of full-batch SGD with momentum on a linear model.
/// `g = t(X) %*% R` is the allreduce-shaped gradient (single-block
/// output, multi-block contraction); `v` and `W` are the resident
/// optimizer state the update chain must keep replicated.
const TRAIN_SRC: &str = "for (e in 1:3) {\n\
                           R = X %*% W - Y\n\
                           g = t(X) %*% R\n\
                           v = mu * v - lr * g\n\
                           W = W + v\n\
                         }\n\
                         loss = sum((X %*% W - Y) ^ 2)";

/// One epoch of the same loop, for the session carry-over variant.
const STEP_SRC: &str = "R = X %*% W - Y\n\
                        g = t(X) %*% R\n\
                        v = mu * v - lr * g\n\
                        W = W + v";

fn dist_config(workers: usize, threads: usize) -> SystemConfig {
    // Tiny driver budget forces the matmult/cellwise chain DIST.
    SystemConfig::builder()
        .driver_memory(8 * 1024)
        .block_size(32)
        .num_workers(workers)
        .dist_threads(threads)
        .build()
}

/// Bind the standard job data (fixed seeds) to any script source.
fn with_inputs(src: &str) -> Script {
    let x = rand(96, 8, -1.0, 1.0, 1.0, Pdf::Uniform, 11).unwrap();
    let y = rand(96, 8, -1.0, 1.0, 1.0, Pdf::Uniform, 12).unwrap();
    let w0 = rand(8, 8, -0.1, 0.1, 1.0, Pdf::Uniform, 13).unwrap();
    Script::from_str(src)
        .input("X", x)
        .input("Y", y)
        .input("W", w0)
        .input("v", Matrix::filled(8, 8, 0.0))
        .input_scalar("mu", 0.9)
        .input_scalar("lr", 0.05)
}

fn train_script() -> Script {
    with_inputs(TRAIN_SRC).output("W").output("loss")
}

struct TrainRun {
    ctx: MLContext,
    w: Matrix,
    loss: f64,
}

fn run_training(config: SystemConfig) -> TrainRun {
    let ctx = MLContext::with_config(config);
    let res = ctx.execute(train_script()).expect("training run");
    // `matrix` forces, but a replicated result materializes free — the
    // zero-collect assertions below hold *after* this call.
    let w = res.matrix("W").unwrap();
    let loss = res.double("loss").unwrap();
    TrainRun { ctx, w, loss }
}

fn bits(m: &Matrix) -> Vec<u64> {
    m.to_row_major_vec().iter().map(|v| v.to_bits()).collect()
}

#[test]
fn resident_training_is_byte_identical_across_cluster_shapes() {
    let reference = run_training(dist_config(4, 1));
    for (workers, threads) in [(1, 1), (2, 1), (7, 1), (2, 4), (4, 4), (7, 4)] {
        let run = run_training(dist_config(workers, threads));
        assert_eq!(
            bits(&run.w),
            bits(&reference.w),
            "weights diverged at workers={workers} threads={threads}"
        );
        assert_eq!(
            run.loss.to_bits(),
            reference.loss.to_bits(),
            "loss diverged at workers={workers} threads={threads}"
        );
    }
}

#[test]
fn multi_epoch_job_never_collects_and_charges_allreduce_rounds() {
    let run = run_training(dist_config(4, 1));
    let cluster = run.ctx.cluster().expect("dist session has a cluster");
    assert_eq!(cluster.collect_count(), 0, "whole job must run at 0 driver collects");
    // Gradients tree-allreduce: rounds recorded and charged into the
    // shuffle volume (the allreduce bytes are a subset of it).
    assert!(cluster.allreduce_round_count() > 0, "gradient aggregation must allreduce");
    let ar = cluster.allreduce_byte_count();
    assert!(ar > 0 && ar <= cluster.comm_bytes(), "allreduce must charge shuffle accounting");

    // One worker needs no reduction rounds at all — and still produces
    // the same bits (checked by the cross-shape test above).
    let solo = run_training(dist_config(1, 1));
    let cluster = solo.ctx.cluster().unwrap();
    assert_eq!(cluster.allreduce_round_count(), 0);
    assert_eq!(cluster.collect_count(), 0);
}

#[test]
fn allreduce_traffic_grows_log2_with_workers() {
    // rounds = ceil(log2(W)): 2 workers -> 1, 4 -> 2, 8 -> 3. The same
    // job moves the same result sizes, so total allreduce bytes scale
    // exactly 1:2:3.
    let volumes: Vec<u64> = [2, 4, 8]
        .iter()
        .map(|&w| {
            let run = run_training(dist_config(w, 1));
            run.ctx.cluster().unwrap().allreduce_byte_count()
        })
        .collect();
    assert!(volumes[0] > 0);
    assert_eq!(volumes[1], 2 * volumes[0], "4 workers = 2x the 2-worker volume");
    assert_eq!(volumes[2], 3 * volumes[0], "8 workers = 3x the 2-worker volume");
}

#[test]
fn resident_training_matches_cp_training() {
    let dist = run_training(dist_config(4, 4));
    let cp = run_training(SystemConfig::builder().dist_enabled(false).build());
    let (d, c) = (dist.w.to_row_major_vec(), cp.w.to_row_major_vec());
    assert_eq!(d.len(), c.len());
    for (i, (a, b)) in d.iter().zip(c.iter()).enumerate() {
        assert!(
            (a - b).abs() <= 1e-9,
            "weight [{i}] diverged beyond fold-order tolerance: dist={a}, cp={b}"
        );
    }
    assert!(
        (dist.loss - cp.loss).abs() <= 1e-9 * cp.loss.abs().max(1.0),
        "loss diverged: dist={}, cp={}",
        dist.loss,
        cp.loss
    );
}

#[test]
fn resident_state_survives_spill_pressure_bit_exactly() {
    let reference = run_training(dist_config(4, 1));
    // 4 KB/worker (16 KB total) is far below the ~30 KB live working
    // set: the resident optimizer state and intermediates get spilled
    // and rebuilt mid-training. The run must still complete with the
    // exact reference bits — spill/restore is value-preserving.
    let squeezed = SystemConfig::builder()
        .driver_memory(8 * 1024)
        .block_size(32)
        .num_workers(4)
        .dist_threads(1)
        .worker_storage(4 * 1024)
        .build();
    let run = run_training(squeezed);
    let cluster = run.ctx.cluster().unwrap();
    assert!(cluster.spill_count() > 0, "storage pressure must actually spill");
    assert_eq!(bits(&run.w), bits(&reference.w), "spilled training diverged");
    assert_eq!(run.loss.to_bits(), reference.loss.to_bits());
}

#[test]
fn session_carries_resident_state_across_scripts() {
    // The same three epochs, split across `execute` calls: the session
    // carries W, v (blocked, resident) and the batch forward — still at
    // zero collects, still bit-identical to the single-script job.
    let reference = run_training(dist_config(4, 1));
    let ctx = MLContext::with_config(dist_config(4, 1));
    let epoch1 = with_inputs(STEP_SRC)
        .output("W")
        .output("v")
        .output("X")
        .output("Y")
        .output("mu")
        .output("lr");
    let res = ctx.execute(epoch1).unwrap();
    assert!(
        matches!(res.value("W").unwrap(), Value::Blocked(_)),
        "updated weights must come back resident"
    );
    for _ in 0..2 {
        // Everything comes from the session now — no inputs at all.
        ctx.execute(Script::from_str(STEP_SRC).output("W").output("v")).unwrap();
    }
    let score = Script::from_str("loss = sum((X %*% W - Y) ^ 2)").output("loss").output("W");
    let res = ctx.execute(score).unwrap();
    let cluster = ctx.cluster().unwrap();
    assert_eq!(cluster.collect_count(), 0, "cross-script session must not collect");
    assert_eq!(bits(&res.matrix("W").unwrap()), bits(&reference.w));
    assert_eq!(res.double("loss").unwrap().to_bits(), reference.loss.to_bits());
}
