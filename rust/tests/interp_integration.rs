//! End-to-end interpreter tests: full DML programs through MLContext.

use systemml::api::{MLContext, Script};
use systemml::runtime::matrix::randgen::synthetic_classification;
use systemml::runtime::matrix::Matrix;

fn run(src: &str, inputs: &[(&str, Matrix)], outputs: &[&str]) -> systemml::api::Results {
    let ctx = MLContext::new();
    let mut script = Script::from_str(src);
    for (n, m) in inputs {
        script = script.input(n, m.clone());
    }
    for o in outputs {
        script = script.output(o);
    }
    ctx.execute(script).unwrap()
}

#[test]
fn control_flow_and_arithmetic() {
    let res = run(
        r#"
        s = 0
        for (i in 1:10) {
          if (i %% 2 == 0) { s = s + i }
        }
        j = 0
        while (j < 5) { j = j + 1 }
        "#,
        &[],
        &["s", "j"],
    );
    assert_eq!(res.double("s").unwrap(), 30.0);
    assert_eq!(res.double("j").unwrap(), 5.0);
}

#[test]
fn matrix_indexing_and_left_indexing() {
    let res = run(
        r#"
        X = matrix(seq(1, 12), rows=3, cols=4)
        a = X[2, 3]
        B = X[1:2, ]
        X[3, ] = matrix(0, rows=1, cols=4)
        rs = rowSums(X)
        "#,
        &[],
        &["a", "B", "rs"],
    );
    assert_eq!(res.matrix("a").unwrap().get(0, 0), 7.0);
    assert_eq!(res.matrix("B").unwrap().shape(), (2, 4));
    assert_eq!(res.matrix("rs").unwrap().get(2, 0), 0.0);
}

#[test]
fn user_functions_with_defaults_and_multireturn() {
    let res = run(
        r#"
        stats = function(matrix[double] X, double scale = 2.0)
            return (double s, double m) {
          s = sum(X) * scale
          m = mean(X)
        }
        [a, b] = stats(matrix(3, rows=2, cols=2))
        c = stats(matrix(1, rows=1, cols=1), scale=10)
        "#,
        &[],
        &["a", "b", "c"],
    );
    assert_eq!(res.double("a").unwrap(), 24.0);
    assert_eq!(res.double("b").unwrap(), 3.0);
    assert_eq!(res.double("c").unwrap(), 10.0);
}

#[test]
fn recursion_bounded() {
    let ctx = MLContext::new();
    let script = Script::from_str(
        "f = function(int n) return (int y) { if (n <= 0) { y = 0 } else { y = f(n - 1) } }\nz = f(10000)",
    )
    .output("z");
    assert!(ctx.execute(script).is_err(), "deep recursion must error, not overflow");
}

#[test]
fn paper_softmax_classifier_script_trains() {
    // The §2 DML listing, lightly adapted (real nn file layout, loss print).
    let src = r#"
source("nn/layers/affine.dml") as affine
source("nn/layers/cross_entropy_loss.dml") as cross_entropy_loss
source("nn/layers/softmax.dml") as softmax
source("nn/optim/sgd.dml") as sgd

train = function(matrix[double] X, matrix[double] Y)
    return (matrix[double] W, matrix[double] b, double first_loss, double last_loss) {
  D = ncol(X)  # num features
  K = ncol(Y)  # num classes
  lr = 0.1; batch_size = 32; num_iter = nrow(X) / batch_size
  [W, b] = affine::init(D, K)
  first_loss = 0; last_loss = 0
  for (i in 1:num_iter) {
    # Get batch
    beg = (i-1)*batch_size + 1; end = beg + batch_size - 1
    X_batch = X[beg:end,]; y_batch = Y[beg:end,]
    # Perform forward pass
    scores = affine::forward(X_batch, W, b)
    probs = softmax::forward(scores)
    loss = cross_entropy_loss::forward(probs, y_batch)
    if (i == 1) { first_loss = loss }
    last_loss = loss
    # Perform backward pass
    dprobs = cross_entropy_loss::backward(probs, y_batch)
    dscores = softmax::backward(dprobs, scores)
    [dX_batch, dW, db] = affine::backward(dscores, X_batch, W, b)
    # Perform update
    W = sgd::update(W, dW, lr)
    b = sgd::update(b, db, lr)
  }
}

[W, b, first_loss, last_loss] = train(X, Y)
"#;
    let (x, y) = synthetic_classification(320, 16, 4, 11);
    let res = run(src, &[("X", x), ("Y", y)], &["W", "b", "first_loss", "last_loss"]);
    let first = res.double("first_loss").unwrap();
    let last = res.double("last_loss").unwrap();
    assert!(first > 0.5, "initial loss should be near ln(4)≈1.39, got {first}");
    assert!(last < first * 0.6, "loss should drop: first {first}, last {last}");
    assert_eq!(res.matrix("W").unwrap().shape(), (16, 4));
}

#[test]
fn parfor_row_partitioned_scoring() {
    let res = run(
        r#"
        n = nrow(X)
        P = matrix(0, rows=n, cols=1)
        parfor (i in 1:n) {
          P[i, ] = sum(X[i, ]) * 2
        }
        total = sum(P)
        "#,
        &[("X", Matrix::filled(64, 8, 0.5))],
        &["P", "total"],
    );
    assert_eq!(res.double("total").unwrap(), 64.0 * 8.0);
    assert_eq!(res.matrix("P").unwrap().get(63, 0), 8.0);
}

#[test]
fn parfor_detects_dependencies() {
    let ctx = MLContext::new();
    let script = Script::from_str(
        "s = 0\nparfor (i in 1:10) { s = s + i }",
    );
    let err = ctx.execute(script);
    assert!(err.is_err(), "scalar accumulation across parfor iterations must be rejected");
}

#[test]
fn parfor_check0_overrides_analysis() {
    // With check=0 the loop runs even though the analysis would reject it;
    // row-disjoint writes still merge correctly.
    let res = run(
        r#"
        P = matrix(0, rows=8, cols=2)
        parfor (i in 1:8, check=0) {
          P[i, ] = matrix(i, rows=1, cols=2)
        }
        t = sum(P)
        "#,
        &[],
        &["t"],
    );
    assert_eq!(res.double("t").unwrap(), 2.0 * (1..=8).sum::<i32>() as f64);
}

#[test]
fn conv_builtins_work_from_dml() {
    let res = run(
        r#"
        N = 2
        X = rand(rows=N, cols=1*6*6, min=0, max=1, seed=3)
        W = rand(rows=4, cols=1*3*3, min=-1, max=1, seed=4)
        out = conv2d(X, W, input_shape=[N,1,6,6], filter_shape=[4,1,3,3],
                     stride=[1,1], padding=[1,1])
        pooled = max_pool(out, input_shape=[N,4,6,6], pool_size=[2,2],
                          stride=[2,2], padding=[0,0])
        s = sum(pooled)
        "#,
        &[],
        &["out", "pooled", "s"],
    );
    assert_eq!(res.matrix("out").unwrap().shape(), (2, 4 * 6 * 6));
    assert_eq!(res.matrix("pooled").unwrap().shape(), (2, 4 * 3 * 3));
}

#[test]
fn hybrid_plan_over_budget_goes_distributed() {
    // Tiny driver budget: the matmult must route through the simulated
    // cluster (and still be numerically exact).
    let mut config = systemml::SystemConfig::tiny_driver(64 * 1024);
    config.num_workers = 4;
    config.block_size = 64;
    let ctx = MLContext::with_config(config);
    let before = systemml::util::metrics::global().snapshot();
    let script = Script::from_str("Y = X %*% X\ns = sum(Y)")
        .input("X", Matrix::filled(128, 128, 0.5))
        .output("s");
    let res = ctx.execute(script).unwrap();
    let delta = systemml::util::metrics::global().snapshot().delta(&before);
    assert!(delta.dist_tasks > 0, "expected distributed tasks for over-budget matmult");
    assert!((res.double("s").unwrap() - 128.0 * 128.0 * 128.0 * 0.25).abs() < 1e-6);
}

#[test]
fn string_ops_and_print() {
    let ctx = MLContext::new();
    let script = Script::from_str(
        r#"
        name = "systemml"
        msg = "hello " + name + " " + 1 + 0.5
        print(msg)
        "#,
    );
    let res = ctx.execute(script).unwrap();
    assert_eq!(res.stdout, vec!["hello systemml 10.5"]);
}

#[test]
fn stop_aborts_execution() {
    let ctx = MLContext::new();
    let script = Script::from_str("stop(\"boom\")\nx = 1").output("x");
    let err = ctx.execute(script).unwrap_err();
    assert!(err.to_string().contains("boom"));
}

#[test]
fn builtin_coverage_sweep() {
    // One expression per remaining builtin family, checking plausibility.
    let res = run(
        r#"
        X = matrix(seq(1, 6), rows=2, cols=3)
        a1 = as.scalar(rowIndexMax(X)[1,1])
        a2 = trace(X %*% t(X))
        a3 = sum(cumsum(X))
        a4 = as.scalar(diag(diag(matrix(seq(1,4), rows=4, cols=1)))[2,1])
        a5 = sum(outer(matrix(1, rows=3, cols=1), matrix(2, rows=1, cols=2), "*"))
        a6 = sum(removeEmpty(rbind(X * 0, X), margin="rows"))
        a7 = sum(table(seq(1,4), matrix(1, rows=4, cols=1), 4, 2))
        a8 = as.scalar(solve(matrix(2, rows=1, cols=1), matrix(8, rows=1, cols=1)))
        a9 = ifelse(sum(X) > 20, 1, 2)
        a10 = sum(rev(X))
        "#,
        &[],
        &["a1", "a2", "a3", "a4", "a5", "a6", "a7", "a8", "a9", "a10"],
    );
    assert_eq!(res.double("a1").unwrap(), 3.0);
    assert_eq!(res.double("a2").unwrap(), 14.0 + 77.0);
    assert_eq!(res.double("a3").unwrap(), 1.0 + 2.0 + 3.0 + 5.0 + 7.0 + 9.0);
    assert_eq!(res.double("a4").unwrap(), 2.0);
    assert_eq!(res.double("a5").unwrap(), 12.0);
    assert_eq!(res.double("a6").unwrap(), 21.0);
    assert_eq!(res.double("a7").unwrap(), 4.0);
    assert_eq!(res.double("a8").unwrap(), 4.0);
    assert_eq!(res.double("a9").unwrap(), 1.0);
    assert_eq!(res.double("a10").unwrap(), 21.0);
}
