//! E1 — Four physical convolution operators (paper §3 "Sparse
//! Operations"): conv2d forward over {dense,sparse} input × {dense,sparse}
//! filter, sweeping input sparsity. Sparse-safe operators must win at high
//! sparsity with FLOPs scaling in nnz.

use systemml::runtime::conv::{conv2d_traced, ConvShape};
use systemml::runtime::matrix::randgen::{rand, Pdf};
use systemml::util::bench::{bench, print_table, Measurement};

fn main() {
    // LeNet conv2-like shape: 16 images, 8->16 channels, 14x14, 3x3.
    let sh = ConvShape { c: 8, h: 14, w: 14, k: 16, r: 3, s: 3, stride: (1, 1), pad: (1, 1) };
    let n = 16;
    let filter_dense = rand(16, 8 * 9, -1.0, 1.0, 1.0, Pdf::Uniform, 1).unwrap();
    let filter_sparse =
        rand(16, 8 * 9, -1.0, 1.0, 0.1, Pdf::Uniform, 2).unwrap().into_sparse_format();

    let mut rows: Vec<Measurement> = Vec::new();
    let mut ops: Vec<String> = Vec::new();
    for input_density in [1.0, 0.35, 0.1, 0.02] {
        let input = rand(n, 8 * 14 * 14, 0.0, 1.0, input_density, Pdf::Uniform, 3).unwrap();
        for (fname, filter) in [("denseF", &filter_dense), ("sparseF", &filter_sparse)] {
            // Force the physical input format the sweep intends.
            let input_cfg = if input_density < 0.4 {
                input.clone().into_sparse_format()
            } else {
                input.clone().into_dense_format()
            };
            let mut selected = None;
            let m = bench(&format!("density={input_density:.2} {fname}"), || {
                let (_, op) = conv2d_traced(&input_cfg, filter, &sh).unwrap();
                selected = Some(op);
            });
            ops.push(format!("{:?}", selected.unwrap()));
            rows.push(m);
        }
    }
    let ops2 = ops.clone();
    print_table(
        "E1: conv2d physical operators vs input sparsity (N=16, 8ch 14x14, K=16 3x3)",
        &rows,
        &["operator", "MFLOP/iter", "GFLOP/s"],
        |m| {
            let idx = rows.iter().position(|r| std::ptr::eq(r, m)).unwrap_or(0);
            vec![
                ops2[idx].clone(),
                format!("{:.2}", m.flops_per_iter() / 1e6),
                format!("{:.2}", m.gflops()),
            ]
        },
    );

    // Shape assertions (the paper claim): sparse input at 2% density must
    // beat the dense-input operator on the same filter.
    let dense_dense = rows[0].median;
    let sparse_dense = rows[6].median;
    println!(
        "\nsparse-input speedup at 2% density vs dense: {:.2}x (expect > 1)",
        dense_dense.as_secs_f64() / sparse_dense.as_secs_f64()
    );
}
