//! E6 — accelerator backend vs interpreted CP (paper §3 "GPU Backend" /
//! "Native BLAS Exploitation"): compute-bound operators offloaded to the
//! AOT-compiled XLA/PJRT executables vs the CP interpreter operators. The
//! paper reports ~10x for GPU-vs-CPU; here both sides share one CPU core,
//! so the reported ratio isolates the *fused compiled kernel vs
//! interpreted operator* effect. Requires `make artifacts`.

use systemml::conf::SystemConfig;
use systemml::runtime::accel::AccelBackend;
use systemml::runtime::conv::{conv2d, ConvShape};
use systemml::runtime::matrix::mult;
use systemml::runtime::matrix::randgen::{rand, synthetic_classification, Pdf};
use systemml::util::bench::{bench, fmt_duration, print_table, Measurement};

fn main() {
    let mut config = SystemConfig::default();
    config.accel_enabled = true;
    let backend = match AccelBackend::open(&config) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("SKIP bench_accel_backend: {e}");
            return;
        }
    };

    let mut rows: Vec<Measurement> = Vec::new();

    // -- matmul 384^3 ------------------------------------------------------
    let a = rand(384, 384, -1.0, 1.0, 1.0, Pdf::Uniform, 1).unwrap();
    let b = rand(384, 384, -1.0, 1.0, 1.0, Pdf::Uniform, 2).unwrap();
    // Naive triple-loop matmult: the "pre-BLAS JVM runtime" baseline the
    // paper's Native-BLAS/GPU backends are contrasted against.
    let (ad, bd) = (a.to_dense(), b.to_dense());
    rows.push(bench("matmul384 naive(j-k inner)", || {
        let (m, k, n) = (384usize, 384usize, 384usize);
        let mut c = vec![0.0f64; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for kk in 0..k {
                    acc += ad.data[i * k + kk] * bd.data[kk * n + j];
                }
                c[i * n + j] = acc;
            }
        }
        std::hint::black_box(&c);
    }));
    rows.push(bench("matmul384 CP", || {
        mult::matmult(&a, &b).unwrap();
    }));
    rows.push(bench("matmul384 ACCEL", || {
        backend.try_matmult(&a, &b).unwrap().expect("matmul_384 artifact");
    }));

    // -- conv2d (LeNet conv1 shape) ----------------------------------------
    let sh = ConvShape { c: 1, h: 28, w: 28, k: 8, r: 3, s: 3, stride: (1, 1), pad: (1, 1) };
    let xi = rand(16, 784, 0.0, 1.0, 1.0, Pdf::Uniform, 3).unwrap();
    let wf = rand(8, 9, -1.0, 1.0, 1.0, Pdf::Uniform, 4).unwrap();
    rows.push(bench("conv2d CP", || {
        conv2d(&xi, &wf, &sh).unwrap();
    }));
    rows.push(bench("conv2d ACCEL", || {
        backend.try_conv2d(&xi, &wf, &sh).unwrap().expect("conv artifact");
    }));

    // -- fused softmax train step vs interpreted DML-equivalent ---------------
    let (xs, ys) = synthetic_classification(32, 784, 10, 5);
    let w0 = rand(784, 10, -0.1, 0.1, 1.0, Pdf::Uniform, 6).unwrap();
    let b0 = systemml::runtime::matrix::Matrix::zeros(1, 10).into_dense_format();
    let ctx = systemml::MLContext::new();
    let step_dml = r#"
        source("nn/layers/softmax.dml") as softmax
        N = nrow(X)
        scores = X %*% W + b
        probs = softmax::forward(scores)
        dscores = (probs - Y) / N
        W2 = W - 0.1 * (t(X) %*% dscores)
        b2 = b - 0.1 * colSums(dscores)
    "#;
    rows.push(bench("train_step DML(CP)", || {
        let script = systemml::Script::from_str(step_dml)
            .input("X", xs.clone())
            .input("Y", ys.clone())
            .input("W", w0.clone())
            .input("b", b0.clone())
            .output("W2");
        ctx.execute(script).unwrap();
    }));
    rows.push(bench("train_step ACCEL(fused)", || {
        backend
            .run_named("softmax_train_step_bs32_d784_k10", &[&xs, &w0, &b0, &ys])
            .unwrap();
    }));

    print_table(
        "E6: interpreted CP vs AOT-compiled XLA/PJRT (both on 1 CPU core)",
        &rows,
        &["GFLOP/s"],
        |m| vec![format!("{:.2}", m.gflops())],
    );
    let naive_vs_accel = rows[0].median.as_secs_f64() / rows[2].median.as_secs_f64();
    println!(
        "\nnaive-runtime -> compiled-kernel speedup (the paper's BLAS/GPU-backend claim): {naive_vs_accel:.1}x"
    );
    for pair in rows[1..].chunks(2) {
        if pair.len() < 2 { break; }
        let ratio = pair[0].median.as_secs_f64() / pair[1].median.as_secs_f64();
        println!(
            "{:24} -> {:24}: {:.2}x ({} vs {})",
            pair[0].label,
            pair[1].label,
            ratio,
            fmt_duration(pair[0].median),
            fmt_duration(pair[1].median)
        );
    }
}
