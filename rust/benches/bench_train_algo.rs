//! E4 — train_algo="minibatch" vs "batch" (paper §3): the same Keras2DML
//! model compiled to the two loop structures. Minibatch does many small
//! updates (better loss per epoch); batch does one large update per epoch
//! whose big matmults are what the distributed backend is for.

use systemml::nn::keras2dml::{FitConfig, Keras2DML, SequentialModel};
use systemml::runtime::matrix::randgen::synthetic_classification;
use systemml::util::bench::{bench_config, print_table, BenchConfig, Measurement};
use systemml::MLContext;

const MODEL: &str = r#"{
    "name": "m", "input_dim": 64,
    "layers": [
        {"type": "dense", "units": 64, "activation": "relu"},
        {"type": "dense", "units": 8, "activation": "softmax"}
    ],
    "optimizer": {"type": "sgd", "lr": 0.05}
}"#;

fn main() {
    let (x, y) = synthetic_classification(2048, 64, 8, 11);
    let cfg = BenchConfig { warmup: 1, min_iters: 3, max_iters: 6, ..Default::default() };
    let mut rows: Vec<Measurement> = Vec::new();
    let mut extra: Vec<(usize, f64)> = Vec::new();
    for (algo, epochs) in [("minibatch", 2usize), ("batch", 2usize)] {
        let model = SequentialModel::from_json(MODEL).unwrap();
        let mut k2d = Keras2DML::new(MLContext::new(), model);
        k2d.fit_config =
            FitConfig { train_algo: algo.into(), epochs, ..FitConfig::default() };
        let mut last = (0usize, 0.0f64);
        let m = bench_config(&format!("train_algo={algo}"), cfg, &mut || {
            let t = k2d.fit(x.clone(), y.clone()).unwrap();
            last = (t.loss_curve.len(), *t.loss_curve.last().unwrap());
        });
        extra.push(last);
        rows.push(m);
    }
    let extra2 = extra.clone();
    print_table(
        "E4: train_algo minibatch vs batch (2048x64, 8 classes, 2 epochs)",
        &rows,
        &["updates", "final loss"],
        |m| {
            let idx = rows.iter().position(|r| std::ptr::eq(r, m)).unwrap_or(0);
            vec![extra2[idx].0.to_string(), format!("{:.4}", extra2[idx].1)]
        },
    );
    assert!(extra[0].0 > extra[1].0, "minibatch must perform more updates");
    assert!(
        extra[0].1 < extra[1].1,
        "minibatch should reach lower loss in equal epochs: {} vs {}",
        extra[0].1,
        extra[1].1
    );
    println!("\nminibatch reaches {:.4} vs batch {:.4} in equal epochs", extra[0].1, extra[1].1);
}
