//! E7 — builtin NN functions vs DML-loop implementations (paper §3
//! "Builtin NN Functions": "we've added them as built-in functions to
//! enable efficient implementations"). Runs the same convolution and
//! pooling as (a) native builtins and (b) the pure-DML nn-library loops.

use systemml::api::{MLContext, Script};
use systemml::util::bench::{bench_config, print_table, BenchConfig, Measurement};

fn main() {
    let ctx = MLContext::new();
    let cfg = BenchConfig { warmup: 1, min_iters: 3, max_iters: 6, ..Default::default() };
    let mut rows: Vec<Measurement> = Vec::new();

    let builtin = r#"
        source("nn/layers/conv2d_builtin.dml") as conv
        X = rand(rows=4, cols=2*12*12, min=-1, max=1, seed=1)
        [W, b] = conv::init(4, 2, 3, 3)
        [out, Hout, Wout] = conv::forward(X, W, b, 2, 12, 12, 3, 3, 1, 1, 1, 1)
        s = sum(out)
    "#;
    let dml_loops = r#"
        source("nn/layers/conv2d.dml") as conv
        source("nn/layers/conv2d_builtin.dml") as convb
        X = rand(rows=4, cols=2*12*12, min=-1, max=1, seed=1)
        [W, b] = convb::init(4, 2, 3, 3)
        [out, Hout, Wout] = conv::forward(X, W, b, 2, 12, 12, 3, 3, 1, 1)
        s = sum(out)
    "#;
    rows.push(bench_config("conv2d builtin", cfg, &mut || {
        ctx.execute(Script::from_str(builtin).output("s")).unwrap();
    }));
    rows.push(bench_config("conv2d DML loops", cfg, &mut || {
        ctx.execute(Script::from_str(dml_loops).output("s")).unwrap();
    }));

    let pool_builtin = r#"
        source("nn/layers/max_pool2d_builtin.dml") as pool
        X = rand(rows=8, cols=2*16*16, min=-1, max=1, seed=2)
        [out, Hout, Wout] = pool::forward(X, 2, 16, 16, 2, 2, 2, 2)
        s = sum(out)
    "#;
    let pool_loops = r#"
        source("nn/layers/max_pool2d.dml") as pool
        X = rand(rows=8, cols=2*16*16, min=-1, max=1, seed=2)
        [out, Hout, Wout] = pool::forward(X, 2, 16, 16, 2, 2, 2, 2)
        s = sum(out)
    "#;
    rows.push(bench_config("max_pool builtin", cfg, &mut || {
        ctx.execute(Script::from_str(pool_builtin).output("s")).unwrap();
    }));
    rows.push(bench_config("max_pool DML loops", cfg, &mut || {
        ctx.execute(Script::from_str(pool_loops).output("s")).unwrap();
    }));

    print_table("E7: builtin NN functions vs DML-loop implementations", &rows, &[], |_| vec![]);
    let conv_ratio = rows[1].median.as_secs_f64() / rows[0].median.as_secs_f64();
    let pool_ratio = rows[3].median.as_secs_f64() / rows[2].median.as_secs_f64();
    println!("\nbuiltin speedup: conv2d {conv_ratio:.0}x, max_pool {pool_ratio:.0}x");
    assert!(conv_ratio > 5.0, "builtin conv must be much faster than DML loops");
}
