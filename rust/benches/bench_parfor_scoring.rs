//! E5 — remote-parfor row-partitioned scoring (paper §3): "the parfor
//! optimizer compiles a row-partitioned remote-parfor plan for the
//! ResNet-50 prediction script that avoids shuffling and scales linearly".
//! Reports per-worker-count wallclock (1 core!), modeled cluster time
//! (max per-worker work / measured rate), and shuffle volume — contrasted
//! with the data-parallel (blocked matmult) plan which must communicate.

use systemml::api::{MLContext, Script};
use systemml::conf::SystemConfig;
use systemml::runtime::matrix::randgen::{rand, synthetic_images, Pdf};
use systemml::util::bench::{bench_config, print_table, BenchConfig, Measurement};
use systemml::util::metrics;

const SCORING: &str = r#"
n = nrow(X)
bs = 16
nb = n %/% bs
P = matrix(0, rows=n, cols=10)
parfor (pi in 1:nb, mode=remote) {
  beg = (pi-1)*bs + 1; end = pi*bs
  Xb = X[beg:end,]
  h1 = max(Xb %*% W1, 0)
  h2 = max(h1 %*% W2, 0)
  P[beg:end, ] = h2 %*% W3
}
"#;

fn main() {
    let n = 256usize;
    let (x, _) = synthetic_images(n, 1, 16, 16, 10, 3);
    let w1 = rand(256, 256, -0.1, 0.1, 1.0, Pdf::Uniform, 4).unwrap();
    let w2 = rand(256, 128, -0.1, 0.1, 1.0, Pdf::Uniform, 5).unwrap();
    let w3 = rand(128, 10, -0.1, 0.1, 1.0, Pdf::Uniform, 6).unwrap();

    let cfg = BenchConfig { warmup: 1, min_iters: 3, max_iters: 8, ..Default::default() };
    let mut rows: Vec<Measurement> = Vec::new();
    let mut modeled: Vec<f64> = Vec::new();
    let mut shuffles: Vec<u64> = Vec::new();
    for workers in [1usize, 2, 4, 8] {
        let mut config = SystemConfig::default();
        config.num_workers = workers;
        let ctx = MLContext::with_config(config);
        let before = metrics::global().snapshot();
        let m = bench_config(&format!("workers={workers}"), cfg, &mut || {
            let script = Script::from_str(SCORING)
                .input("X", x.clone())
                .input("W1", w1.clone())
                .input("W2", w2.clone())
                .input("W3", w3.clone())
                .output("P");
            ctx.execute(script).unwrap();
        });
        let d = metrics::global().snapshot().delta(&before);
        let rate = (d.flops as f64 / m.iters as f64) / m.median.as_secs_f64();
        modeled.push((d.flops as f64 / m.iters as f64) / workers as f64 / rate);
        shuffles.push(d.shuffle_bytes);
        rows.push(m);
    }
    let modeled2 = modeled.clone();
    let shuffles2 = shuffles.clone();
    print_table(
        "E5: remote-parfor scoring, 256 rows, 3-layer net (modeled cluster time)",
        &rows,
        &["modeled time", "speedup", "shuffle bytes"],
        |m| {
            let idx = rows.iter().position(|r| std::ptr::eq(r, m)).unwrap_or(0);
            vec![
                format!("{:.4}s", modeled2[idx]),
                format!("{:.1}x", modeled2[0] / modeled2[idx]),
                shuffles2[idx].to_string(),
            ]
        },
    );
    assert!(shuffles.iter().all(|s| *s == 0), "row-partitioned plan must not shuffle");
    println!(
        "\nmodeled speedup at 8 workers: {:.1}x (paper claim: linear scaling, no shuffle)",
        modeled[0] / modeled[3]
    );
}
