//! E2 — sparse vs dense matmult physical operators (paper §3): density
//! sweep over A %*% B at 384^2, reporting the selected operator, time,
//! and FLOPs. Sparse wins at low density; dense wins near-dense — the
//! crossover is the sparsity turn point story.

use systemml::runtime::matrix::mult::matmult_traced;
use systemml::runtime::matrix::randgen::{rand, Pdf};
use systemml::util::bench::{bench, print_table, Measurement};

fn main() {
    let n = 384usize;
    let mut rows: Vec<Measurement> = Vec::new();
    let mut ops: Vec<String> = Vec::new();
    for density in [1.0, 0.6, 0.4, 0.2, 0.1, 0.05, 0.01] {
        let a = rand(n, n, -1.0, 1.0, density, Pdf::Uniform, 1).unwrap();
        let b = rand(n, n, -1.0, 1.0, density, Pdf::Uniform, 2).unwrap();
        let mut selected = None;
        let m = bench(&format!("density={density:.2}"), || {
            let (_, op) = matmult_traced(&a, &b).unwrap();
            selected = Some(op);
        });
        ops.push(format!("{:?}", selected.unwrap()));
        rows.push(m);
    }
    let ops2 = ops.clone();
    print_table(
        "E2: matmult operator selection vs density (384x384 @ 384x384)",
        &rows,
        &["operator", "MFLOP/iter", "GFLOP/s"],
        |m| {
            let idx = rows.iter().position(|r| std::ptr::eq(r, m)).unwrap_or(0);
            vec![
                ops2[idx].clone(),
                format!("{:.2}", m.flops_per_iter() / 1e6),
                format!("{:.2}", m.gflops()),
            ]
        },
    );
    let dense_t = rows[0].median.as_secs_f64();
    let sparse_t = rows[6].median.as_secs_f64();
    println!("\n1% density speedup over dense-dense: {:.1}x", dense_t / sparse_t);
}
