//! E3 — hybrid plan selection (paper §3 "Distributed Operations"): the
//! same minibatch-shaped matmult runs CP while it fits the driver budget
//! and flips to the distributed blocked plan beyond it. The bench sweeps
//! the input rows across the crossover and reports the chosen plan,
//! wallclock, and communication volume.

use systemml::api::{MLContext, Script};
use systemml::conf::SystemConfig;
use systemml::runtime::matrix::randgen::{rand, Pdf};
use systemml::util::bench::{bench, print_table, Measurement};
use systemml::util::metrics;

fn main() {
    // Budget sized so the crossover falls inside the sweep:
    // est = rows*256*8 (X) + 256*64*8 (W) + rows*64*8 (out).
    let budget = 3 * 1024 * 1024;
    let mut config = SystemConfig::tiny_driver(budget);
    config.block_size = 256;
    let mut rows_out: Vec<Measurement> = Vec::new();
    let mut plans: Vec<String> = Vec::new();
    let mut comm: Vec<u64> = Vec::new();
    for nrows in [256usize, 512, 1024, 2048, 4096] {
        let x = rand(nrows, 256, -1.0, 1.0, 1.0, Pdf::Uniform, 1).unwrap();
        let w = rand(256, 64, -1.0, 1.0, 1.0, Pdf::Uniform, 2).unwrap();
        let ctx = MLContext::with_config(config.clone());
        let before = metrics::global().snapshot();
        let m = bench(&format!("rows={nrows}"), || {
            let script = Script::from_str("Y = X %*% W\ns = sum(Y)")
                .input("X", x.clone())
                .input("W", w.clone())
                .output("s");
            ctx.execute(script).unwrap();
        });
        let d = metrics::global().snapshot().delta(&before);
        plans.push(if d.dist_tasks > 0 { "DIST".into() } else { "CP".into() });
        comm.push(d.broadcast_bytes + d.shuffle_bytes);
        rows_out.push(m);
    }
    let plans2 = plans.clone();
    let comm2 = comm.clone();
    print_table(
        &format!("E3: hybrid plan selection, driver budget {} MB", budget / 1024 / 1024),
        &rows_out,
        &["plan", "comm bytes"],
        |m| {
            let idx = rows_out.iter().position(|r| std::ptr::eq(r, m)).unwrap_or(0);
            vec![plans2[idx].clone(), comm2[idx].to_string()]
        },
    );
    assert_eq!(plans[0], "CP");
    assert_eq!(plans.last().unwrap(), "DIST");
    let flip = plans.iter().position(|p| p == "DIST").unwrap();
    println!("\ncrossover: CP -> DIST between rows={} and rows={}", 256 << (flip - 1), 256 << flip);
}
