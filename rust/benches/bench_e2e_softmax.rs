//! E8 — end-to-end §2 workflow equivalence: the hand-written DML script
//! and the Keras2DML-generated script implement the same algorithm.
//! Reports steps/s for both entry paths and checks the loss trajectories
//! land in the same place for the same data.

use systemml::api::{MLContext, Script};
use systemml::nn::keras2dml::{FitConfig, Keras2DML, SequentialModel};
use systemml::runtime::matrix::randgen::synthetic_classification;
use systemml::util::bench::{bench_config, print_table, BenchConfig, Measurement};

const HAND_DML: &str = r#"
source("nn/layers/affine.dml") as affine
source("nn/layers/cross_entropy_loss.dml") as ce
source("nn/layers/softmax.dml") as softmax
source("nn/optim/sgd.dml") as sgd
D = ncol(X); K = ncol(Y)
lr = 0.05; batch_size = 32; num_iter = nrow(X) / batch_size
[W, b] = affine::init(D, K)
last_loss = 0
for (i in 1:num_iter) {
  beg = (i-1)*batch_size + 1; end = beg + batch_size - 1
  Xb = X[beg:end,]; Yb = Y[beg:end,]
  probs = softmax::forward(affine::forward(Xb, W, b))
  last_loss = ce::forward(probs, Yb)
  dscores = softmax::backward(ce::backward(probs, Yb), affine::forward(Xb, W, b))
  [dX, dW, db] = affine::backward(dscores, Xb, W, b)
  W = sgd::update(W, dW, lr)
  b = sgd::update(b, db, lr)
}
"#;

const KERAS_JSON: &str = r#"{
    "name": "softmax", "input_dim": 32,
    "layers": [{"type": "dense", "units": 6, "activation": "softmax"}],
    "optimizer": {"type": "sgd", "lr": 0.05}
}"#;

fn main() {
    let (x, y) = synthetic_classification(1024, 32, 6, 21);
    let ctx = MLContext::new();
    let cfg = BenchConfig { warmup: 1, min_iters: 3, max_iters: 8, ..Default::default() };
    let steps = 1024 / 32;

    let mut rows: Vec<Measurement> = Vec::new();
    let mut hand_loss = 0.0;
    rows.push(bench_config("hand-written DML", cfg, &mut || {
        let script = Script::from_str(HAND_DML)
            .input("X", x.clone())
            .input("Y", y.clone())
            .output("last_loss");
        hand_loss = ctx.execute(script).unwrap().double("last_loss").unwrap();
    }));

    let model = SequentialModel::from_json(KERAS_JSON).unwrap();
    let mut k2d = Keras2DML::new(MLContext::new(), model);
    k2d.fit_config = FitConfig { epochs: 1, ..FitConfig::default() };
    let mut keras_loss = 0.0;
    rows.push(bench_config("Keras2DML generated", cfg, &mut || {
        let t = k2d.fit(x.clone(), y.clone()).unwrap();
        keras_loss = *t.loss_curve.last().unwrap();
    }));

    print_table(
        "E8: paper §2 workflow — hand-written DML vs Keras2DML codegen",
        &rows,
        &["steps/s", "final loss"],
        |m| {
            let loss = if m.label.starts_with("hand") { hand_loss } else { keras_loss };
            vec![
                format!("{:.1}", steps as f64 / m.median.as_secs_f64()),
                format!("{:.4}", loss),
            ]
        },
    );
    // Same algorithm, same data: both must converge to a low loss.
    assert!(hand_loss < 0.5 && keras_loss < 0.5, "{hand_loss} vs {keras_loss}");
    let overhead = rows[1].median.as_secs_f64() / rows[0].median.as_secs_f64();
    println!("\nKeras2DML overhead vs hand DML: {overhead:.2}x (codegen only — same runtime)");
}
