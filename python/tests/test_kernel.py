"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes, asserting allclose against ref.py —
the CORE correctness signal for the compile path.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.matmul import matmul, vmem_footprint_bytes
from compile.kernels.softmax import softmax_rows

dims = st.integers(min_value=1, max_value=96)
dtypes = st.sampled_from([jnp.float32, jnp.float64])


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


@settings(max_examples=25, deadline=None)
@given(m=dims, k=dims, n=dims, dtype=dtypes, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(m, k, n, dtype, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (m, k), dtype)
    y = _rand(rng, (k, n), dtype)
    got = matmul(x, y)
    want = ref.matmul_ref(x, y)
    tol = 1e-5 if dtype == jnp.float32 else 1e-12
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@settings(max_examples=20, deadline=None)
@given(n=dims, d=dims, dtype=dtypes, seed=st.integers(0, 2**31 - 1))
def test_softmax_matches_ref(n, d, dtype, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (n, d), dtype) * 10.0
    got = softmax_rows(x)
    want = ref.softmax_ref(x)
    tol = 1e-5 if dtype == jnp.float32 else 1e-12
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)
    np.testing.assert_allclose(jnp.sum(got, axis=-1), jnp.ones(n), rtol=tol, atol=tol)


def test_matmul_nonsquare_blocks():
    rng = np.random.default_rng(0)
    x = _rand(rng, (130, 17), jnp.float64)  # forces non-128 divisors
    y = _rand(rng, (17, 33), jnp.float64)
    np.testing.assert_allclose(matmul(x, y), ref.matmul_ref(x, y), rtol=1e-12)


def test_vmem_footprint_within_tpu_budget():
    # The default schedule must fit a 16 MiB VMEM for the artifact shapes.
    for (m, k, n) in [(256, 256, 256), (384, 384, 384), (32, 784, 10)]:
        assert vmem_footprint_bytes(m, k, n) < 16 * 1024 * 1024
