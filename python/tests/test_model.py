"""L2 graph correctness: fused models vs oracles, in the exact layouts the
rust runtime expects."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _rand(rng, shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float64)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(1, 4),
    c=st.integers(1, 3),
    hw=st.integers(5, 9),
    k=st.integers(1, 4),
    rs=st.sampled_from([1, 3]),
    stride=st.sampled_from([1, 2]),
    pad=st.sampled_from([0, 1]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_matches_ref(n, c, hw, k, rs, stride, pad, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (n, c * hw * hw))
    w = _rand(rng, (k, c * rs * rs))
    got = model.conv2d(x, w, n=n, c=c, h=hw, w=hw, k=k, r=rs, s=rs, stride=stride, pad=pad)[0]
    want = ref.conv2d_ref(x, w, n, c, hw, hw, k, rs, rs, stride, pad)
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-10)


def test_softmax_train_step_matches_ref():
    rng = np.random.default_rng(7)
    x = _rand(rng, (32, 20))
    w = _rand(rng, (20, 5)) * 0.1
    b = jnp.zeros((1, 5), dtype=jnp.float64)
    y = jnp.eye(5, dtype=jnp.float64)[rng.integers(0, 5, 32)]
    got = model.softmax_train_step(x, w, b, y, lr=0.1)
    want = ref.softmax_train_step_ref(x, w, b, y, 0.1)
    for g, wv in zip(got, want):
        np.testing.assert_allclose(g, wv, rtol=1e-10, atol=1e-12)


def test_train_step_reduces_loss():
    rng = np.random.default_rng(8)
    x = _rand(rng, (32, 10))
    w = jnp.zeros((10, 3), dtype=jnp.float64)
    b = jnp.zeros((1, 3), dtype=jnp.float64)
    y = jnp.eye(3, dtype=jnp.float64)[rng.integers(0, 3, 32)]
    losses = []
    for _ in range(30):
        w, b, loss = model.softmax_train_step(x, w, b, y, lr=0.5)
        losses.append(float(loss[0, 0]))
    assert losses[-1] < losses[0] * 0.8, losses[:3] + losses[-3:]


def test_mlp_train_step_shapes_and_descent():
    rng = np.random.default_rng(9)
    bs, d, h, k = 16, 12, 8, 3
    x = _rand(rng, (bs, d))
    w1 = _rand(rng, (d, h)) * 0.1
    b1 = jnp.zeros((1, h), dtype=jnp.float64)
    w2 = _rand(rng, (h, k)) * 0.1
    b2 = jnp.zeros((1, k), dtype=jnp.float64)
    y = jnp.eye(k, dtype=jnp.float64)[rng.integers(0, k, bs)]
    first = None
    for _ in range(40):
        w1, b1, w2, b2, loss = model.mlp_train_step(x, w1, b1, w2, b2, y, lr=0.5)
        if first is None:
            first = float(loss[0, 0])
    assert float(loss[0, 0]) < first
    assert w1.shape == (d, h) and w2.shape == (h, k)
