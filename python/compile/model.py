"""L2: JAX compute graphs for the operators the ACCEL backend offloads.

Each entry point composes the L1 Pallas kernels (tiled matmul, row
softmax) into the fused graphs SystemML's GPU backend would run as
CuBLAS/CuDNN call sequences:

* ``matmul`` — the BLAS-3 workhorse;
* ``conv2d`` — im2col lowering [5] + Pallas GEMM, producing the same
  K-major N x (K*P*Q) linearized layout as the rust runtime;
* ``softmax_train_step`` — one fused minibatch SGD step of the paper's §2
  softmax classifier (forward + backward + update), the "fused operator"
  case where python stays off the request path: rust feeds and consumes
  device buffers only.

All graphs are f64 (DML's value type): aot.py enables jax_enable_x64.
"""

import jax.numpy as jnp

from compile.kernels.matmul import matmul as pallas_matmul
from compile.kernels.softmax import softmax_rows as pallas_softmax

# Kernel selection: the ``pallas`` flag picks the L1 Pallas kernels
# (interpret=True — the TPU-shaped kernels, CPU-emulated) or the XLA-native
# jnp ops. aot.py emits BOTH variants per entry: the native one is what the
# rust ACCEL backend dispatches on CPU (interpret-mode Pallas emulation is
# not a serving path); the ``*_pallas`` twin exists so pytest + the rust
# tests can assert the two lower to identical numerics. On a real TPU the
# Pallas variant would be the deployed one (DESIGN.md §Hardware-Adaptation).


def _mm(pallas):
    return pallas_matmul if pallas else jnp.matmul


def _softmax(x, pallas):
    if pallas:
        return pallas_softmax(x)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def matmul(x, y, *, pallas=True):
    """GEMM via the L1 Pallas kernel (or the XLA-native op)."""
    return (_mm(pallas)(x, y),)


def conv2d(x_lin, w_lin, *, n, c, h, w, k, r, s, stride, pad, pallas=True):
    """conv2d forward over the linearized layout via im2col + Pallas GEMM."""
    p = (h + 2 * pad - r) // stride + 1
    q = (w + 2 * pad - s) // stride + 1
    x4 = x_lin.reshape(n, c, h, w)
    xp = jnp.pad(x4, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    # im2col: gather (C,R,S) patches for every output position.
    # cols: (N, P*Q, C*R*S)
    patches = []
    for dr in range(r):
        for ds in range(s):
            sl = xp[:, :, dr : dr + stride * p : stride, ds : ds + stride * q : stride]
            patches.append(sl.reshape(n, c, p * q))
    # (R*S, N, C, PQ) -> (N, PQ, C, R*S) -> (N, PQ, C*R*S)
    col = jnp.stack(patches, axis=-1)  # (N, C, PQ, R*S)
    col = col.transpose(0, 2, 1, 3).reshape(n, p * q, c * r * s)
    # One GEMM per batch via a single reshaped GEMM: (N*PQ, CRS) @ (CRS, K).
    flat = col.reshape(n * p * q, c * r * s)
    prod = _mm(pallas)(flat, w_lin.T)  # (N*PQ, K)
    out = prod.reshape(n, p * q, k).transpose(0, 2, 1).reshape(n, k * p * q)
    return (out,)


def softmax_train_step(x, w, b, y, *, lr, pallas=True):
    """Fused minibatch step: returns (W', b', loss[1,1])."""
    mm = _mm(pallas)
    nrows = x.shape[0]
    scores = mm(x, w) + b
    probs = _softmax(scores, pallas)
    eps = 1e-12
    loss = -jnp.mean(jnp.sum(y * jnp.log(probs + eps), axis=-1))
    dscores = (probs - y) / nrows
    dw = mm(x.T, dscores)
    db = jnp.sum(dscores, axis=0, keepdims=True)
    return (w - lr * dw, b - lr * db, loss.reshape(1, 1))


def mlp_train_step(x, w1, b1, w2, b2, y, *, lr, pallas=True):
    """Fused 2-layer MLP (relu) minibatch step: the LeNet-class fused path.

    Returns (W1', b1', W2', b2', loss[1,1]).
    """
    mm = _mm(pallas)
    nrows = x.shape[0]
    h_pre = mm(x, w1) + b1
    h = jnp.maximum(h_pre, 0.0)
    scores = mm(h, w2) + b2
    probs = _softmax(scores, pallas)
    eps = 1e-12
    loss = -jnp.mean(jnp.sum(y * jnp.log(probs + eps), axis=-1))
    dscores = (probs - y) / nrows
    dw2 = mm(h.T, dscores)
    db2 = jnp.sum(dscores, axis=0, keepdims=True)
    dh = mm(dscores, w2.T) * (h_pre > 0.0)
    dw1 = mm(x.T, dh)
    db1 = jnp.sum(dh, axis=0, keepdims=True)
    return (
        w1 - lr * dw1,
        b1 - lr * db1,
        w2 - lr * dw2,
        b2 - lr * db2,
        loss.reshape(1, 1),
    )
