"""AOT driver: lower the L2 graphs to HLO text + write the manifest.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the rust `xla` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Python runs ONCE here (`make artifacts`); the rust binary then loads and
executes the artifacts via PJRT with no python on the request path.
"""

import argparse
import functools
import json
import os

import jax

jax.config.update("jax_enable_x64", True)  # DML matrices are double

from jax._src.lib import xla_client as xc  # noqa: E402

from compile import model  # noqa: E402


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(r, c):
    return jax.ShapeDtypeStruct((r, c), jax.numpy.float64)


def entries():
    """The artifact set: (name, fn, input shapes, op, attrs, n_outputs)."""
    out = []

    # GEMM shapes: the accel-vs-CP experiment (E6) + classifier layers.
    for (m, k, n) in [(256, 256, 256), (384, 384, 384), (32, 784, 10)]:
        out.append(
            dict(
                name=f"matmul_{m}x{k}x{n}",
                fn=functools.partial(model.matmul, pallas=False),
                inputs=[(m, k), (k, n)],
                op="matmul",
                attrs=dict(m=m, k=k, n=n),
                num_outputs=1,
            )
        )

    # LeNet-ish conv shapes (E6 conv offload).
    for (n, c, h, w, k, r, s, stride, pad) in [
        (16, 1, 28, 28, 8, 3, 3, 1, 1),
        (16, 8, 14, 14, 16, 3, 3, 1, 1),
    ]:
        fn = functools.partial(
            model.conv2d, n=n, c=c, h=h, w=w, k=k, r=r, s=s, stride=stride,
            pad=pad, pallas=False,
        )
        out.append(
            dict(
                name=f"conv2d_n{n}c{c}h{h}w{w}_k{k}r{r}s{s}_st{stride}p{pad}",
                fn=fn,
                inputs=[(n, c * h * w), (k, c * r * s)],
                op="conv2d",
                attrs=dict(n=n, c=c, h=h, w=w, k=k, r=r, s=s, stride=stride, pad=pad),
                num_outputs=1,
            )
        )

    # Fused softmax-classifier train step (paper §2 script, one iteration).
    bs, d, kk = 32, 784, 10
    out.append(
        dict(
            name=f"softmax_train_step_bs{bs}_d{d}_k{kk}",
            fn=functools.partial(model.softmax_train_step, lr=0.1, pallas=False),
            inputs=[(bs, d), (d, kk), (1, kk), (bs, kk)],
            op="softmax_train_step",
            attrs=dict(bs=bs, d=d, k=kk),
            num_outputs=3,
        )
    )

    # Fused MLP train step (hidden 256).
    hid = 256
    out.append(
        dict(
            name=f"mlp_train_step_bs{bs}_d{d}_h{hid}_k{kk}",
            fn=functools.partial(model.mlp_train_step, lr=0.1, pallas=False),
            inputs=[(bs, d), (d, hid), (1, hid), (hid, kk), (1, kk), (bs, kk)],
            op="mlp_train_step",
            attrs=dict(bs=bs, d=d, hidden=hid, k=kk),
            num_outputs=5,
        )
    )
    # Pallas-kernel twins (L1 validation artifacts): same graphs with the
    # interpret-mode Pallas kernels inlined. The rust tests assert the twin
    # computes exactly what the native variant computes.
    pallas_twins = []
    for e in out:
        if e["op"] in ("matmul", "softmax_train_step"):
            fn = e["fn"]
            twin = dict(e)
            twin["name"] = e["name"] + "_pallas"
            twin["op"] = e["op"] + "_pallas"
            twin["fn"] = functools.partial(fn.func, *fn.args, **{**fn.keywords, "pallas": True}) if isinstance(fn, functools.partial) else functools.partial(fn, pallas=True)
            pallas_twins.append(twin)
    out.extend(pallas_twins)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    # legacy single-file arg kept for Makefile compat; unused beyond touch
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"entries": []}
    for e in entries():
        specs = [spec(r, c) for (r, c) in e["inputs"]]
        lowered = jax.jit(e["fn"]).lower(*specs)
        text = to_hlo_text(lowered)
        fname = e["name"] + ".hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        manifest["entries"].append(
            dict(
                name=e["name"],
                file=fname,
                op=e["op"],
                attrs=e["attrs"],
                inputs=[[r, c] for (r, c) in e["inputs"]],
                num_outputs=e["num_outputs"],
            )
        )
        print(f"wrote {fname} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if args.out:
        # Makefile stamp target.
        with open(args.out, "w") as f:
            f.write("see manifest.json\n")
    print(f"manifest: {len(manifest['entries'])} entries")


if __name__ == "__main__":
    main()
