"""L1 Pallas kernel: row-wise numerically-stable softmax.

Row-blocked so each grid step normalizes a VMEM-resident panel of rows;
fused with the classifier GEMMs at L2 (model.py) into a single HLO module.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...]
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


def _largest_divisor_le(n: int, cap: int) -> int:
    d = min(n, cap)
    while n % d != 0:
        d -= 1
    return d


@functools.partial(jax.jit, static_argnames=("block_rows",))
def softmax_rows(x, block_rows: int = 128):
    """Row-wise softmax via pallas_call. x: (n, d) -> (n, d)."""
    n, d = x.shape
    br = _largest_divisor_le(n, block_rows)
    return pl.pallas_call(
        _softmax_kernel,
        grid=(n // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=True,
    )(x)
