"""Pure-jnp correctness oracles for the Pallas kernels and L2 graphs."""

import jax.numpy as jnp
from jax import lax


def matmul_ref(x, y):
    """Plain jnp matmul oracle."""
    return jnp.matmul(x, y)


def softmax_ref(x):
    """Row-wise stable softmax oracle."""
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def conv2d_ref(x_lin, w_lin, n, c, h, w, k, r, s, stride, pad):
    """Convolution oracle over the linearized SystemML layout.

    x_lin: (N, C*H*W), w_lin: (K, C*R*S) -> (N, K*P*Q), matching the
    paper's tensor representation (§3) and the rust runtime's conv2d.
    """
    x4 = x_lin.reshape(n, c, h, w)
    w4 = w_lin.reshape(k, c, r, s)
    out = lax.conv_general_dilated(
        x4,
        w4,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    nn, kk, p, q = out.shape
    return out.reshape(nn, kk * p * q)


def softmax_train_step_ref(x, w, b, y, lr):
    """One fused minibatch step of the paper's §2 softmax classifier."""
    nrows = x.shape[0]
    scores = x @ w + b
    probs = softmax_ref(scores)
    eps = 1e-12
    loss = -jnp.mean(jnp.sum(y * jnp.log(probs + eps), axis=-1))
    dscores = (probs - y) / nrows
    dw = x.T @ dscores
    db = jnp.sum(dscores, axis=0, keepdims=True)
    return w - lr * dw, b - lr * db, loss.reshape(1, 1)
