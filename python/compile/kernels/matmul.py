"""L1 Pallas kernel: MXU-tiled matrix multiplication.

The paper's compute hot spot (§3: "Most of the time in training a deep
neural network is spent in matrix multiplications and convolution
operations") on a GPU maps to CuBLAS GEMM; the TPU rethink (DESIGN.md
§Hardware-Adaptation) tiles the operands into VMEM-resident blocks sized
for the 128x128 MXU systolic array. BlockSpecs express the HBM->VMEM
schedule that CUDA expressed with threadblocks.

Kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, so interpret mode (plain HLO) is the correctness and
AOT path; real-TPU efficiency is estimated structurally in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned tile edge.
MXU_TILE = 128


def _largest_divisor_le(n: int, cap: int) -> int:
    """Largest divisor of n that is <= cap (block sizes must tile evenly)."""
    d = min(n, cap)
    while n % d != 0:
        d -= 1
    return d


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (bm, bn) output tile: full-K contraction of VMEM-resident tiles.

    The f32/f64 accumulation happens inside the dot; with bm = bn = 128 the
    MXU is fully occupied on real hardware.
    """
    o_ref[...] = jnp.dot(x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def matmul(x, y, bm: int = MXU_TILE, bn: int = MXU_TILE):
    """Tiled matmul via pallas_call. x: (m, k), y: (k, n) -> (m, n)."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = _largest_divisor_le(m, bm)
    bn = _largest_divisor_le(n, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            # Row-block of x: varies with i, full K panel resident in VMEM.
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            # Col-block of y: varies with j.
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,  # CPU-PJRT executable HLO; see module docstring
    )(x, y)


def vmem_footprint_bytes(m: int, k: int, n: int, itemsize: int = 8,
                         bm: int = MXU_TILE, bn: int = MXU_TILE) -> int:
    """Estimated VMEM residency per grid step (inputs + output tile).

    Used by DESIGN.md §Perf to check the schedule fits the ~16 MiB VMEM of
    a TPU core: bm*k + k*bn + bm*bn elements.
    """
    bm = _largest_divisor_le(m, bm)
    bn = _largest_divisor_le(n, bn)
    return itemsize * (bm * k + k * bn + bm * bn)
